//! The async multiplexed front door: one readiness-polled event loop
//! serving many connections, many in-flight requests per connection.
//!
//! The legacy server dedicated a thread per connection and handled one
//! request at a time — request *k + 1* could not even be parsed until
//! request *k*'s solve and simulation finished. This module replaces
//! that with a single nonblocking event-loop thread (`ftl-frontend`):
//!
//! * **Multiplexing** — v1 frames (`FTL1 <id> <command...>`, see
//!   [`super::proto`] and `PROTOCOL.md`) carry a client-chosen request
//!   id. Deploys are handed to [`BatchScheduler::submit_async`] and the
//!   loop moves on; responses come back tagged with their id, in
//!   whatever order the scheduler finishes them.
//! * **Streaming** — each v1 deploy gets a [`StreamSink`]: the `plan`
//!   event is pushed the moment the solve lands, per-phase `sim` events
//!   follow, then the terminal `done`/`error`. Warm requests skip the
//!   work and collapse to a single terminal frame.
//! * **v0 compatibility** — bare legacy lines are served in order, one
//!   JSON line per request, by serializing them per connection (a v0
//!   deploy in flight parks the line behind it; v1 traffic on other
//!   connections is unaffected).
//! * **Backpressure, both directions** — per-connection in-flight
//!   requests are capped ([`FrontendOptions::max_inflight`]): at the
//!   cap the loop simply stops reading that socket, so the kernel
//!   buffer (and eventually the client) absorbs the excess. Output is
//!   queued per connection up to
//!   [`FrontendOptions::write_queue_cap`] bytes; a client that stops
//!   reading long enough to overflow the queue is closed and counted
//!   (`slow_closed`) instead of wedging the loop.
//! * **Fault isolation** — malformed or oversized frames cost their
//!   sender one `error` event (on the recoverable id, 0 otherwise) and
//!   never the connection.
//!
//! On Linux the loop sleeps in `poll(2)` (via a minimal FFI shim — no
//! external crates) with each socket's read/write interest registered,
//! so it wakes exactly when a socket or the cross-thread waker is
//! ready; readiness is then discovered by the normal nonblocking scan,
//! so the `revents` bits are advisory only. Elsewhere it degrades to a
//! short fixed sleep. Completions and streamed events land from
//! scheduler threads through a socketpair waker, never by touching the
//! sockets themselves — all socket I/O stays on the loop thread.

// The poll(2) FFI shim below is the crate's single unsafe block; every
// other module carries `#![forbid(unsafe_code)]`.
#![deny(unsafe_op_in_unsafe_fn)]

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use super::batch::{build_deploy, handle_typed, outcome_to_json, BatchScheduler, DeployRequest};
use super::proto::{self, Event, EventSink, MAX_FRAME_BYTES};
use crate::metrics::Counter;
use crate::util::json::Json;

/// Tuning for the front door event loop.
#[derive(Debug, Clone)]
pub struct FrontendOptions {
    /// Per-connection output queue bound, in bytes. A connection whose
    /// queued-but-unwritten responses exceed this is closed as a slow
    /// client.
    pub write_queue_cap: usize,
    /// Per-connection cap on concurrently in-flight v1 deploys. At the
    /// cap the loop stops reading the socket until a slot frees.
    pub max_inflight: usize,
    /// Upper bound on how long the loop sleeps with nothing ready —
    /// the worst-case latency for noticing a stop request on platforms
    /// without the waker fd in the poll set.
    pub tick: Duration,
}

impl Default for FrontendOptions {
    fn default() -> Self {
        Self { write_queue_cap: 4 * 1024 * 1024, max_inflight: 128, tick: Duration::from_millis(10) }
    }
}

/// Cumulative front-door telemetry, reported under `"frontend"` in
/// `STATS`.
#[derive(Debug, Default)]
pub struct FrontendCounters {
    pub accepted: Counter,
    pub closed: Counter,
    /// Connections closed for overflowing their write queue.
    pub slow_closed: Counter,
    /// Complete request lines consumed (both framings, errors included).
    pub frames_in: Counter,
    /// Response lines written (streamed events included).
    pub frames_out: Counter,
    /// Malformed or oversized frames answered with an error event.
    pub protocol_errors: Counter,
    /// Terminal scheduler completions that arrived after their
    /// connection was torn down (shed slow client, socket error) and
    /// were dropped. The request's slot/serial-lane state is still
    /// released and its trace span was already finished by the
    /// scheduler — this only counts the discarded reply line.
    pub dropped_completions: Counter,
}

impl FrontendCounters {
    /// Currently open connections.
    pub fn open(&self) -> u64 {
        self.accepted.get().saturating_sub(self.closed.get())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("accepted", Json::Num(self.accepted.get() as f64)),
            ("open", Json::Num(self.open() as f64)),
            ("closed", Json::Num(self.closed.get() as f64)),
            ("slow_closed", Json::Num(self.slow_closed.get() as f64)),
            ("frames_in", Json::Num(self.frames_in.get() as f64)),
            ("frames_out", Json::Num(self.frames_out.get() as f64)),
            ("protocol_errors", Json::Num(self.protocol_errors.get() as f64)),
            ("dropped_completions", Json::Num(self.dropped_completions.get() as f64)),
        ])
    }
}

/// Cross-thread wakeup for the event loop: completions and streamed
/// events write one byte into a nonblocking socketpair, whose read end
/// sits in the loop's poll set. Writes when the pipe is already full
/// fail with `WouldBlock` — fine, a wakeup is already pending.
#[cfg(unix)]
struct Waker {
    tx: std::os::unix::net::UnixStream,
    rx: std::os::unix::net::UnixStream,
}

#[cfg(unix)]
impl Waker {
    fn new() -> std::io::Result<Self> {
        let (tx, rx) = std::os::unix::net::UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok(Self { tx, rx })
    }

    fn wake(&self) {
        // One byte is all-or-nothing; `WouldBlock` on a full pipe means
        // a wakeup is already pending — both fine to ignore.
        let _ = (&self.tx).write_all(&[1u8]);
    }

    fn drain(&self) {
        let mut buf = [0u8; 64];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }

    #[cfg(target_os = "linux")]
    fn raw_fd(&self) -> i32 {
        use std::os::unix::io::AsRawFd;
        self.rx.as_raw_fd()
    }
}

/// Degraded waker for platforms without socketpairs: the loop falls
/// back to bounded sleeps, so wakeups are only latency hints.
#[cfg(not(unix))]
struct Waker;

#[cfg(not(unix))]
impl Waker {
    fn new() -> std::io::Result<Self> {
        Ok(Self)
    }
    fn wake(&self) {}
    fn drain(&self) {}
}

/// Minimal `poll(2)` shim — interest registration only; the loop
/// rescans every socket nonblockingly after waking, so `revents` is
/// never inspected and spurious wakeups are merely a wasted scan.
#[cfg(target_os = "linux")]
mod sys {
    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;

    #[repr(C)]
    #[allow(dead_code)] // written for the kernel, never read back
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: std::ffi::c_ulong, timeout: std::ffi::c_int) -> std::ffi::c_int;
    }

    /// Sleep until any registered fd is ready or `timeout_ms` elapses.
    /// Errors (EINTR included) just end the sleep early.
    pub fn wait(fds: &mut [PollFd], timeout_ms: i32) {
        // SAFETY: `fds` is a live, exclusively borrowed slice of
        // `#[repr(C)]` PollFd, so the pointer/length pair describes
        // exactly `fds.len()` writable entries for the kernel; poll(2)
        // writes only the `revents` field within those bounds and the
        // return value (including errors) is deliberately ignored.
        unsafe {
            poll(fds.as_mut_ptr(), fds.len() as std::ffi::c_ulong, timeout_ms);
        }
    }
}

/// The slice of per-connection state shared with scheduler threads:
/// completions and stream sinks push rendered response lines here and
/// wake the loop; the loop drains lines to the socket.
struct ConnShared {
    state: Mutex<ConnState>,
    waker: Arc<Waker>,
    write_queue_cap: usize,
    /// Front-door counters, shared so completions landing on a dead
    /// connection can be counted (`dropped_completions`) off-loop.
    counters: Arc<FrontendCounters>,
}

struct ConnState {
    /// Rendered response lines (no terminator) awaiting the socket.
    out: VecDeque<String>,
    /// Bytes queued in `out` (terminators included) — the overflow gauge.
    out_bytes: usize,
    /// v1 deploys handed to the scheduler, not yet terminal.
    inflight: usize,
    /// A v0 deploy is in flight; later lines on this connection wait.
    v0_busy: bool,
    /// Write queue overflowed — the loop closes the connection.
    overflowed: bool,
    /// Connection is gone; late completions drop their output.
    dead: bool,
}

impl ConnShared {
    fn new(waker: Arc<Waker>, write_queue_cap: usize, counters: Arc<FrontendCounters>) -> Self {
        Self {
            state: Mutex::new(ConnState {
                out: VecDeque::new(),
                out_bytes: 0,
                inflight: 0,
                v0_busy: false,
                overflowed: false,
                dead: false,
            }),
            waker,
            write_queue_cap,
            counters,
        }
    }

    fn push_locked(&self, st: &mut ConnState, line: String) {
        st.out_bytes += line.len() + 1;
        st.out.push_back(line);
        if st.out_bytes > self.write_queue_cap {
            st.overflowed = true;
        }
    }

    /// Queue one response line (streamed events, inline replies).
    fn push(&self, line: String) {
        let mut st = self.state.lock().unwrap();
        if st.dead {
            return;
        }
        self.push_locked(&mut st, line);
        drop(st);
        self.waker.wake();
    }

    /// Terminal line for one v1 deploy: queue it and release the slot.
    /// On a dead connection the slot is still released (no drift in the
    /// shared state a retry/diagnosis might read) but the reply is
    /// dropped and counted instead of queued into limbo.
    fn finish_one(&self, line: String) {
        let mut st = self.state.lock().unwrap();
        st.inflight = st.inflight.saturating_sub(1);
        if st.dead {
            drop(st);
            self.counters.dropped_completions.inc();
            return;
        }
        self.push_locked(&mut st, line);
        drop(st);
        self.waker.wake();
    }

    /// Terminal line for the v0 deploy: queue it and unpark the
    /// connection's serial lane. Dead connections drop-and-count like
    /// [`ConnShared::finish_one`], still clearing the busy flag.
    fn v0_done(&self, line: String) {
        let mut st = self.state.lock().unwrap();
        st.v0_busy = false;
        if st.dead {
            drop(st);
            self.counters.dropped_completions.inc();
            return;
        }
        self.push_locked(&mut st, line);
        drop(st);
        self.waker.wake();
    }
}

/// Streams a v1 deploy's partial replies (`plan`, `sim`) onto its
/// connection, tagged with the request id. Terminal frames come from
/// the completion callback, not the sink.
struct StreamSink {
    shared: Arc<ConnShared>,
    id: u64,
}

impl EventSink for StreamSink {
    fn emit(&self, event: &Event) {
        self.shared.push(event.render(self.id));
    }
}

/// Loop-owned per-connection state (never touched off-thread).
struct Conn {
    stream: TcpStream,
    shared: Arc<ConnShared>,
    /// Unparsed input bytes.
    rbuf: Vec<u8>,
    /// A complete line that could not proceed yet (v1 at the in-flight
    /// cap, or any line parked behind a v0 deploy). Retried each tick;
    /// also the read-pause signal.
    pending_line: Option<String>,
    /// Swallowing the remainder of an oversized unterminated line.
    discarding: bool,
    /// Peer sent EOF; drain and close once quiet.
    half_closed: bool,
    /// Unrecoverable socket error.
    dead: bool,
    /// The line currently on the wire, and how much of it is written.
    wbuf: Vec<u8>,
    wpos: usize,
}

impl Conn {
    fn new(stream: TcpStream, shared: Arc<ConnShared>) -> Self {
        Self {
            stream,
            shared,
            rbuf: Vec::new(),
            pending_line: None,
            discarding: false,
            half_closed: false,
            dead: false,
            wbuf: Vec::new(),
            wpos: 0,
        }
    }

    fn wants_read(&self) -> bool {
        !self.half_closed && !self.dead && self.pending_line.is_none()
    }

    fn write_idle(&self) -> bool {
        self.wpos == self.wbuf.len()
    }
}

/// The front door itself: construct with a scheduler, then
/// [`serve`](Frontend::serve) a listener.
pub struct Frontend {
    scheduler: Arc<BatchScheduler>,
    opts: FrontendOptions,
}

/// A running front door. Dropping (or [`join`](FrontendHandle::join)ing)
/// stops the event loop; connections are closed, in-flight scheduler
/// work completes into dead connections and is dropped.
pub struct FrontendHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    waker: Arc<Waker>,
    counters: Arc<FrontendCounters>,
    thread: Option<JoinHandle<()>>,
}

impl FrontendHandle {
    /// The bound listen address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn counters(&self) -> &FrontendCounters {
        &self.counters
    }

    /// Ask the loop to exit. Returns immediately; the loop notices via
    /// the waker (or within one tick).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        self.waker.wake();
    }

    /// Stop the loop and wait for the thread to exit.
    pub fn join(mut self) {
        self.shutdown();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for FrontendHandle {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Frontend {
    pub fn new(scheduler: Arc<BatchScheduler>, opts: FrontendOptions) -> Self {
        Self { scheduler, opts }
    }

    /// Start the event loop on its own thread, serving `listener`.
    pub fn serve(self, listener: TcpListener) -> Result<FrontendHandle> {
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let waker = Arc::new(Waker::new()?);
        let counters = Arc::new(FrontendCounters::default());
        let looper = EventLoop {
            scheduler: self.scheduler,
            opts: self.opts,
            counters: Arc::clone(&counters),
            stop: Arc::clone(&stop),
            waker: Arc::clone(&waker),
        };
        let thread = std::thread::Builder::new()
            .name("ftl-frontend".into())
            .spawn(move || looper.run(listener))?;
        Ok(FrontendHandle { addr, stop, waker, counters, thread: Some(thread) })
    }
}

struct EventLoop {
    scheduler: Arc<BatchScheduler>,
    opts: FrontendOptions,
    counters: Arc<FrontendCounters>,
    stop: Arc<AtomicBool>,
    waker: Arc<Waker>,
}

impl EventLoop {
    fn run(&self, listener: TcpListener) {
        let mut conns: Vec<Conn> = Vec::new();
        while !self.stop.load(Ordering::Relaxed) {
            let mut progressed = self.accept_into(&listener, &mut conns);
            self.waker.drain();
            for conn in conns.iter_mut() {
                // Write first (free queue space), read, process, then
                // write again so inline replies leave this tick.
                progressed |= self.flush(conn);
                progressed |= self.fill(conn);
                progressed |= self.process(conn);
                progressed |= self.flush(conn);
            }
            let mut i = 0;
            while i < conns.len() {
                if self.should_close(&conns[i]) {
                    let conn = conns.swap_remove(i);
                    self.retire(conn);
                    progressed = true;
                } else {
                    i += 1;
                }
            }
            if !progressed && !self.stop.load(Ordering::Relaxed) {
                self.idle_wait(&listener, &conns);
            }
        }
        for conn in conns {
            self.retire(conn);
        }
    }

    fn accept_into(&self, listener: &TcpListener, conns: &mut Vec<Conn>) -> bool {
        let mut progressed = false;
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let shared = Arc::new(ConnShared::new(
                        Arc::clone(&self.waker),
                        self.opts.write_queue_cap,
                        Arc::clone(&self.counters),
                    ));
                    conns.push(Conn::new(stream, shared));
                    self.counters.accepted.inc();
                    progressed = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        progressed
    }

    /// Read whatever the socket has, up to a bounded buffer. Reading is
    /// paused while a line is parked (`pending_line`) — that is the
    /// in-flight backpressure reaching the peer.
    fn fill(&self, conn: &mut Conn) -> bool {
        if !conn.wants_read() {
            return false;
        }
        let mut progressed = false;
        let mut buf = [0u8; 8192];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.half_closed = true;
                    break;
                }
                Ok(n) => {
                    progressed = true;
                    conn.rbuf.extend_from_slice(&buf[..n]);
                    // An unterminated line past the frame bound is
                    // handled by `process`; don't buffer past 2× it.
                    if conn.rbuf.len() > 2 * MAX_FRAME_BYTES {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        progressed
    }

    /// Consume complete lines from the read buffer. Returns true if
    /// any line was consumed.
    fn process(&self, conn: &mut Conn) -> bool {
        let mut progressed = false;
        loop {
            if conn.discarding {
                match conn.rbuf.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        conn.rbuf.drain(..=pos);
                        conn.discarding = false;
                    }
                    None => {
                        conn.rbuf.clear();
                        break;
                    }
                }
            }
            let line = match conn.pending_line.take() {
                Some(line) => line,
                None => match self.next_line(conn) {
                    Some(line) => line,
                    None => break,
                },
            };
            if self.handle_line(conn, &line) {
                self.counters.frames_in.inc();
                progressed = true;
            } else {
                conn.pending_line = Some(line);
                break;
            }
        }
        progressed
    }

    /// Extract the next complete line, handling oversize on the spot
    /// (error event, never a disconnect). `None` means no complete
    /// line is buffered.
    fn next_line(&self, conn: &mut Conn) -> Option<String> {
        loop {
            match conn.rbuf.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    let raw: Vec<u8> = conn.rbuf.drain(..=pos).collect();
                    let line = String::from_utf8_lossy(&raw[..pos]).trim().to_string();
                    if pos > MAX_FRAME_BYTES {
                        self.reject_oversized(conn, &line);
                        continue;
                    }
                    if line.is_empty() {
                        continue;
                    }
                    return Some(line);
                }
                None => {
                    if conn.rbuf.len() > MAX_FRAME_BYTES {
                        // Unterminated oversized line: reject on what
                        // we can see, swallow the rest as it arrives.
                        let prefix = String::from_utf8_lossy(&conn.rbuf[..256.min(conn.rbuf.len())]).to_string();
                        self.reject_oversized(conn, &prefix);
                        conn.rbuf.clear();
                        conn.discarding = true;
                    }
                    return None;
                }
            }
        }
    }

    fn reject_oversized(&self, conn: &Conn, seen: &str) {
        self.counters.protocol_errors.inc();
        let message = format!("oversized frame: request lines are limited to {MAX_FRAME_BYTES} bytes");
        let reply = if seen.split_whitespace().next() == Some(proto::V1_TAG) {
            Event::Error { message }.render(proto::id_hint(seen).unwrap_or(0))
        } else {
            Json::obj(vec![("error", Json::str(message))]).to_string()
        };
        conn.shared.push(reply);
    }

    /// Handle one complete request line. Returns false when the line
    /// cannot proceed yet (in-flight cap, v0 serialization) — the
    /// caller parks it and stops reading.
    fn handle_line(&self, conn: &Conn, line: &str) -> bool {
        let frame = match proto::Frame::parse(line) {
            Ok(frame) => frame,
            Err(e) => {
                self.counters.protocol_errors.inc();
                let msg = format!("{e:#}");
                let reply = if line.split_whitespace().next() == Some(proto::V1_TAG) {
                    Event::Error { message: msg }.render(proto::id_hint(line).unwrap_or(0))
                } else {
                    Json::obj(vec![("error", Json::str(msg))]).to_string()
                };
                conn.shared.push(reply);
                return true;
            }
        };
        match frame.version {
            proto::Version::V1 => {
                let id = frame.id.unwrap_or(0);
                match &frame.request {
                    proto::Request::Deploy(cmd) => self.start_deploy_v1(conn, id, cmd),
                    request => {
                        let legacy = self.respond_inline(request);
                        conn.shared.push(proto::wrap_v1(id, &legacy));
                        true
                    }
                }
            }
            proto::Version::V0 => {
                if conn.shared.state.lock().unwrap().v0_busy {
                    // Legacy clients expect responses in request order:
                    // everything behind an in-flight v0 deploy waits.
                    return false;
                }
                match &frame.request {
                    proto::Request::Deploy(cmd) => self.start_deploy_v0(conn, cmd),
                    request => {
                        let legacy = self.respond_inline(request);
                        conn.shared.push(legacy);
                        true
                    }
                }
            }
        }
    }

    /// Non-deploy commands answer inline on the loop thread (cache and
    /// counter reads — cheap). `STATS` grows the front door's own block.
    fn respond_inline(&self, request: &proto::Request) -> String {
        if matches!(request, proto::Request::Stats) {
            let mut j = self.scheduler.stats_json();
            if let Json::Obj(m) = &mut j {
                m.insert("frontend".into(), self.counters.to_json());
            }
            return j.to_string();
        }
        handle_typed(&self.scheduler, request)
    }

    fn start_deploy_v1(&self, conn: &Conn, id: u64, cmd: &proto::DeployCommand) -> bool {
        {
            let st = conn.shared.state.lock().unwrap();
            if st.inflight >= self.opts.max_inflight {
                return false;
            }
        }
        let (graph, cfg) = match build_deploy(cmd) {
            Ok(built) => built,
            Err(e) => {
                conn.shared.push(Event::Error { message: format!("{e:#}") }.render(id));
                return true;
            }
        };
        let soc = cfg.soc.clone();
        let lane_name = self.scheduler.lane_name(cmd.lane.as_deref()).to_string();
        conn.shared.state.lock().unwrap().inflight += 1;
        let sink: Arc<dyn EventSink> = Arc::new(StreamSink { shared: Arc::clone(&conn.shared), id });
        let mut req = DeployRequest::new(cmd.workload.clone(), graph, cfg).sink(sink);
        if let Some(lane) = &cmd.lane {
            req = req.lane(lane.clone());
        }
        if let Some(deadline) = cmd.deadline() {
            req = req.deadline(deadline);
        }
        let shared = Arc::clone(&conn.shared);
        self.scheduler.submit_async(
            req,
            Box::new(move |result, trace_id| {
                let line = match result {
                    Ok(outcome) => Event::Done(outcome_to_json(&outcome, &lane_name, trace_id, &soc)).render(id),
                    Err(e) => Event::Error { message: format!("{e:#}") }.render(id),
                };
                shared.finish_one(line);
            }),
        );
        true
    }

    fn start_deploy_v0(&self, conn: &Conn, cmd: &proto::DeployCommand) -> bool {
        let (graph, cfg) = match build_deploy(cmd) {
            Ok(built) => built,
            Err(e) => {
                conn.shared
                    .push(Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string());
                return true;
            }
        };
        let soc = cfg.soc.clone();
        let lane_name = self.scheduler.lane_name(cmd.lane.as_deref()).to_string();
        conn.shared.state.lock().unwrap().v0_busy = true;
        let mut req = DeployRequest::new(cmd.workload.clone(), graph, cfg);
        if let Some(lane) = &cmd.lane {
            req = req.lane(lane.clone());
        }
        if let Some(deadline) = cmd.deadline() {
            req = req.deadline(deadline);
        }
        let shared = Arc::clone(&conn.shared);
        self.scheduler.submit_async(
            req,
            Box::new(move |result, trace_id| {
                let line = match result {
                    Ok(outcome) => outcome_to_json(&outcome, &lane_name, trace_id, &soc).to_string(),
                    Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string(),
                };
                shared.v0_done(line);
            }),
        );
        true
    }

    /// Drain queued response lines to the socket until it would block.
    fn flush(&self, conn: &mut Conn) -> bool {
        if conn.dead {
            return false;
        }
        let mut progressed = false;
        loop {
            if conn.write_idle() {
                let next = {
                    let mut st = conn.shared.state.lock().unwrap();
                    let line = st.out.pop_front();
                    if let Some(line) = &line {
                        st.out_bytes = st.out_bytes.saturating_sub(line.len() + 1);
                    }
                    line
                };
                match next {
                    Some(line) => {
                        conn.wbuf = line.into_bytes();
                        conn.wbuf.push(b'\n');
                        conn.wpos = 0;
                        self.counters.frames_out.inc();
                    }
                    None => break,
                }
            }
            match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                Ok(0) => {
                    conn.dead = true;
                    break;
                }
                Ok(n) => {
                    conn.wpos += n;
                    progressed = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        progressed
    }

    fn should_close(&self, conn: &Conn) -> bool {
        if conn.dead {
            return true;
        }
        let st = conn.shared.state.lock().unwrap();
        if st.overflowed || st.dead {
            return true;
        }
        // Graceful: peer EOF'd (possibly via shutdown(WR) while still
        // reading), everything parsed is answered and flushed.
        conn.half_closed
            && conn.rbuf.is_empty()
            && conn.pending_line.is_none()
            && st.inflight == 0
            && !st.v0_busy
            && st.out.is_empty()
            && conn.write_idle()
    }

    fn retire(&self, conn: Conn) {
        let mut st = conn.shared.state.lock().unwrap();
        st.dead = true;
        if st.overflowed && !conn.dead {
            self.counters.slow_closed.inc();
        }
        drop(st);
        self.counters.closed.inc();
    }

    /// Sleep until something is plausibly ready: any socket's
    /// registered interest, the waker, or the tick expiring.
    #[cfg(target_os = "linux")]
    fn idle_wait(&self, listener: &TcpListener, conns: &[Conn]) {
        use std::os::unix::io::AsRawFd;
        let mut fds = Vec::with_capacity(conns.len() + 2);
        fds.push(sys::PollFd { fd: listener.as_raw_fd(), events: sys::POLLIN, revents: 0 });
        fds.push(sys::PollFd { fd: self.waker.raw_fd(), events: sys::POLLIN, revents: 0 });
        for conn in conns {
            let mut events = 0i16;
            if conn.wants_read() {
                events |= sys::POLLIN;
            }
            let st = conn.shared.state.lock().unwrap();
            if !conn.write_idle() || !st.out.is_empty() {
                events |= sys::POLLOUT;
            }
            fds.push(sys::PollFd { fd: conn.stream.as_raw_fd(), events, revents: 0 });
        }
        let timeout_ms = self.opts.tick.as_millis().clamp(1, i32::MAX as u128) as i32;
        sys::wait(&mut fds, timeout_ms);
    }

    /// Portable fallback: short bounded sleep (wakeups become latency
    /// hints rather than interrupts).
    #[cfg(not(target_os = "linux"))]
    fn idle_wait(&self, _listener: &TcpListener, _conns: &[Conn]) {
        std::thread::sleep(self.opts.tick.min(Duration::from_millis(2)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{BatchOptions, PlanService, ServeOptions};
    use std::io::BufRead;

    fn frontend_with(opts: FrontendOptions, batch: BatchOptions) -> (FrontendHandle, Arc<BatchScheduler>) {
        let service = Arc::new(PlanService::new(ServeOptions {
            cache_capacity: 32,
            cache_shards: 2,
            workers: 1,
            ..ServeOptions::default()
        }));
        let scheduler = Arc::new(BatchScheduler::new(service, batch));
        let handle = Frontend::new(Arc::clone(&scheduler), opts)
            .serve(TcpListener::bind("127.0.0.1:0").unwrap())
            .unwrap();
        (handle, scheduler)
    }

    fn frontend() -> FrontendHandle {
        frontend_with(
            FrontendOptions::default(),
            BatchOptions { batch_window: Duration::ZERO, ..BatchOptions::default() },
        )
        .0
    }

    fn connect(handle: &FrontendHandle) -> (TcpStream, std::io::BufReader<TcpStream>) {
        let stream = TcpStream::connect(handle.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = std::io::BufReader::new(stream.try_clone().unwrap());
        (stream, reader)
    }

    fn read_json(reader: &mut std::io::BufReader<TcpStream>) -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        crate::util::json::parse(line.trim()).unwrap()
    }

    fn event_of(j: &Json) -> String {
        j.get("event").unwrap().as_str().unwrap().to_string()
    }

    #[test]
    fn v1_deploy_streams_plan_phases_done_and_warm_collapses() {
        let handle = frontend();
        let (mut stream, mut reader) = connect(&handle);
        stream.write_all(b"FTL1 7 DEPLOY stage-16x24x48 cluster-only ftl\n").unwrap();
        let mut events = Vec::new();
        loop {
            let j = read_json(&mut reader);
            assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 7);
            assert_eq!(j.get("v").unwrap().as_u64().unwrap(), 1);
            let ev = event_of(&j);
            let done = ev == "done" || ev == "error";
            events.push((ev, j));
            if done {
                break;
            }
        }
        let kinds: Vec<&str> = events.iter().map(|(e, _)| e.as_str()).collect();
        assert_eq!(kinds.first(), Some(&"plan"), "cold deploy must stream the plan first: {kinds:?}");
        assert!(kinds[1..kinds.len() - 1].iter().all(|k| *k == "sim"), "between plan and done: {kinds:?}");
        assert!(kinds.len() >= 3, "expected at least one sim event: {kinds:?}");
        let (_, done) = events.last().unwrap();
        assert_eq!(event_of(done), "done");
        assert_eq!(done.get("outcome").unwrap().as_str().unwrap(), "OK");

        // Warm repeat: single terminal frame, no partials.
        stream.write_all(b"FTL1 8 DEPLOY stage-16x24x48 cluster-only ftl\n").unwrap();
        let j = read_json(&mut reader);
        assert_eq!(event_of(&j), "done");
        assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 8);
        assert!(j.get("cached").unwrap().as_bool().unwrap());
        assert!(j.get("sim_cached").unwrap().as_bool().unwrap());
        handle.join();
    }

    #[test]
    fn malformed_and_oversized_frames_error_without_disconnecting() {
        let handle = frontend();
        let (mut stream, mut reader) = connect(&handle);
        stream.write_all(b"FTL1 11 FROB x\n").unwrap();
        let j = read_json(&mut reader);
        assert_eq!(event_of(&j), "error");
        assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 11, "error must land on the recoverable id");

        let mut big = b"FTL1 12 DEPLOY ".to_vec();
        big.resize(MAX_FRAME_BYTES + 64, b'x');
        big.push(b'\n');
        stream.write_all(&big).unwrap();
        let j = read_json(&mut reader);
        assert_eq!(event_of(&j), "error");
        assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 12);
        assert!(j.get("error").unwrap().as_str().unwrap().contains("oversized"));

        // The connection survives both: a PING still answers.
        stream.write_all(b"FTL1 13 PING\n").unwrap();
        let j = read_json(&mut reader);
        assert_eq!(event_of(&j), "done");
        assert!(j.get("pong").unwrap().as_bool().unwrap());
        assert!(handle.counters().protocol_errors.get() >= 2);
        handle.join();
    }

    #[test]
    fn late_completions_on_a_dead_connection_are_counted_not_queued() {
        let counters = Arc::new(FrontendCounters::default());
        let shared = ConnShared::new(Arc::new(Waker::new().unwrap()), 1024, Arc::clone(&counters));
        {
            let mut st = shared.state.lock().unwrap();
            st.dead = true;
            st.inflight = 1;
            st.v0_busy = true;
        }
        shared.finish_one("late v1 done".into());
        shared.v0_done("late v0 done".into());
        assert_eq!(counters.dropped_completions.get(), 2, "both late terminals are counted");
        let st = shared.state.lock().unwrap();
        assert_eq!(st.inflight, 0, "the v1 slot is still released on a dead connection");
        assert!(!st.v0_busy, "the v0 serial lane is still unparked on a dead connection");
        assert!(st.out.is_empty(), "nothing may be queued for a dead socket");
        assert_eq!(st.out_bytes, 0);
    }

    #[test]
    fn shed_with_inflight_tears_down_cleanly() {
        // A write queue small enough that a single STATS reply
        // overflows it, and a batch window long enough that a cold
        // deploy is still in flight when the shed happens.
        let (handle, scheduler) = frontend_with(
            FrontendOptions { write_queue_cap: 256, ..FrontendOptions::default() },
            BatchOptions { batch_window: Duration::from_millis(250), ..BatchOptions::default() },
        );
        let (mut stream, _reader) = connect(&handle);
        stream.write_all(b"FTL1 1 DEPLOY stage-16x24x48 cluster-only ftl\n").unwrap();
        // Wedge the connection: replies we never read overflow the cap.
        for id in 2..40u64 {
            stream.write_all(format!("FTL1 {id} STATS\n").as_bytes()).unwrap();
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while handle.counters().slow_closed.get() == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(handle.counters().slow_closed.get(), 1, "overflow must shed the slow connection");
        // The deploy, still parked in the batch window at shed time,
        // completes into the dead connection: dropped and counted.
        while handle.counters().dropped_completions.get() == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(handle.counters().dropped_completions.get(), 1);
        assert_eq!(handle.counters().open(), 0, "accepted/closed must balance after the shed");
        // The scheduler still finished the span — a shed connection
        // must not leave permanently-open spans in the journal.
        let tracer = scheduler.tracer().expect("tracing is on by default");
        assert!(tracer.spans_started() >= 1);
        assert_eq!(tracer.spans_started(), tracer.spans_finished(), "no span may stay open");
        handle.join();
    }

    #[test]
    fn v0_lines_keep_their_legacy_shape_and_order() {
        let handle = frontend();
        let (mut stream, mut reader) = connect(&handle);
        stream.write_all(b"PING\nDEPLOY stage-16x24x48 cluster-only ftl\nSTATS\n").unwrap();
        let pong = read_json(&mut reader);
        assert!(pong.get("pong").unwrap().as_bool().unwrap());
        assert!(pong.get_opt("v").is_none(), "v0 replies must not grow protocol fields");
        let deploy = read_json(&mut reader);
        assert_eq!(deploy.get("outcome").unwrap().as_str().unwrap(), "OK");
        assert!(deploy.get_opt("event").is_none());
        let stats = read_json(&mut reader);
        assert!(stats.get("frontend").unwrap().get("accepted").unwrap().as_u64().unwrap() >= 1);
        handle.join();
    }
}
