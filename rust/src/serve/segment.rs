//! Snapshot **segment files** — the on-disk container of the `ftl-bin-v1`
//! snapshot codec ([`crate::serve::persist`]).
//!
//! Instead of one file per cache entry (the `ftl-snapshot-v1` JSON
//! layout), a segment batches many entries into one append-ably *named*
//! file (`seg-<seq>.ftlseg`; each flush pass seals a new segment, so the
//! directory as a whole is the append log) and ends with a **footer
//! index** mapping `(kind, fingerprint)` to the entry's byte range plus
//! its lane-weight hint. Warm-start then costs a few sequential file
//! reads and in-memory decodes instead of 10⁵ `open`+parse calls — and
//! the hints in the index let the loader order decodes
//! heaviest-lane-first without touching a single payload.
//!
//! # Wire layout
//!
//! ```text
//! segment := "FTLSEG1\n"            8-byte file magic
//!            format                 length-prefixed str ("ftl-bin-v1")
//!            entry*                 back-to-back entry records
//!            index                  footer (see below)
//!            index_len              fixed 8-byte LE u64
//!            "FTLIDX1\n"            8-byte trailer magic
//!
//! entry   := kind u8                0 = plan, 1 = sim
//!            fingerprint u128      fixed 16 bytes LE (the cache key)
//!            checksum u128         FNV-1a/128 over kind‖fingerprint‖payload
//!            hint varint           lane-weight warm-up hint
//!            payload               varint byte length + ftl-bin-v1 body
//!
//! index   := count varint
//!            (kind u8, fingerprint u128, hint varint,
//!             offset varint, len varint)*        range of the whole entry
//! ```
//!
//! The trailer is fixed-width so a reader seeks it from the end of the
//! file; the per-entry checksum covers the kind and fingerprint as well
//! as the payload (same property as the JSON envelope: a corrupted key
//! cannot smuggle a valid payload in under the wrong fingerprint).
//!
//! # Failure model
//!
//! Segments are written to a `.tmp-<pid>` sibling, fsync'd, then
//! `rename`d — a crash mid-write never leaves a half-written segment
//! under a final name. Reading is nonetheless defensive against
//! truncation and bit rot: a missing or unparseable footer drops the
//! reader into a **sequential entry scan** from the header, recovering
//! every record before the tear ([`SegmentView::recovered`]); the
//! undecodable tail is reported ([`SegmentView::torn_tail`]) so the
//! loader can count the skip. Entry payloads are *not* validated here —
//! [`decode_entry`] checks the checksum when the loader (possibly on a
//! different [`crate::tiling::SolverPool`] worker) actually decodes the
//! entry.

#![forbid(unsafe_code)]

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::bincode::{BinReader, BinWriter};

use super::fingerprint::{checksum, Fingerprint};

/// Binary snapshot codec version tag, embedded in every segment header.
/// Bump whenever the binary encoding of any persisted type changes
/// incompatibly — old segments are then skipped (counted as
/// `skipped_version`) instead of mis-decoded.
pub const SEGMENT_FORMAT: &str = "ftl-bin-v1";

/// Segment file extension (`seg-<seq>.ftlseg`).
pub const SEGMENT_EXT: &str = "ftlseg";

const SEG_MAGIC: &[u8; 8] = b"FTLSEG1\n";
const IDX_MAGIC: &[u8; 8] = b"FTLIDX1\n";
/// Fixed trailer: 8-byte LE index length + 8-byte magic.
const TRAILER_LEN: usize = 16;

/// One entry to be sealed into a segment.
#[derive(Debug, Clone)]
pub struct SegmentEntry {
    /// Entry kind (`persist::KIND_PLAN` / `persist::KIND_SIM`).
    pub kind: u8,
    /// Cache key.
    pub key: Fingerprint,
    /// Lane-weight warm-up hint (0 = never hit through a lane).
    pub hint: u64,
    /// `ftl-bin-v1` payload (e.g. `Deployment::to_bin`).
    pub payload: Vec<u8>,
}

/// One footer-index record: where an entry lives inside the segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IndexEntry {
    /// Entry kind.
    pub kind: u8,
    /// Cache key.
    pub key: Fingerprint,
    /// Lane-weight warm-up hint.
    pub hint: u64,
    /// Byte offset of the whole entry record from the file start.
    pub offset: usize,
    /// Byte length of the whole entry record.
    pub len: usize,
}

/// A read segment: the raw bytes plus the (footer or recovered) index.
#[derive(Debug)]
pub struct SegmentView {
    /// The whole segment file.
    pub data: Vec<u8>,
    /// Entry locations, in file order.
    pub entries: Vec<IndexEntry>,
    /// True when the footer was unusable and the entries were recovered
    /// by a sequential scan instead.
    pub recovered: bool,
    /// True when a sequential scan hit undecodable bytes before the end
    /// of the file — a torn/truncated segment whose tail is lost.
    pub torn_tail: bool,
}

/// Why a whole segment file was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentError {
    /// Valid segment magic but a different codec version tag.
    Version,
    /// Not a segment / unreadable / header too corrupt to scan.
    Corrupt,
}

/// The sequence number a `seg-…` file name claims: the leading digit
/// run between `seg-` and `.ftlseg`, parsed saturating into a `u128`.
/// Deliberately forgiving — a hand-restored `seg-00000042.bak.ftlseg`,
/// a torn file whose *content* is unreadable, or a counter that
/// overflowed past `u64` all still claim their number. `None` only
/// when there are no leading digits at all.
fn segment_seq(name: &str) -> Option<u128> {
    let body = name.strip_prefix("seg-")?.strip_suffix(&format!(".{SEGMENT_EXT}"))?;
    let run = body.as_bytes().iter().take_while(|b| b.is_ascii_digit()).count();
    if run == 0 {
        return None;
    }
    let mut seq: u128 = 0;
    for b in &body.as_bytes()[..run] {
        seq = seq.saturating_mul(10).saturating_add(u128::from(b - b'0'));
    }
    Some(seq)
}

/// All segment files in `dir`, sorted by **numeric** sequence number
/// (name tiebreak) — which is write order, because
/// [`next_segment_path`] allocates monotonically increasing sequence
/// numbers. Numeric (not lexicographic) order matters for the
/// newest-wins merge: a restored or overflowed name longer than the
/// zero-padded eight digits would otherwise sort out of write order
/// and silently resurrect stale entries. Files claiming no sequence at
/// all sort first, i.e. oldest — they can never outrank a fresh append.
pub fn segment_paths(dir: &Path) -> Vec<PathBuf> {
    let Ok(entries) = std::fs::read_dir(dir) else { return Vec::new() };
    let mut paths: Vec<PathBuf> = entries
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("seg-") && n.ends_with(&format!(".{SEGMENT_EXT}")))
        })
        .collect();
    paths.sort_by_cached_key(|p| {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
        (segment_seq(&name).unwrap_or(0), name)
    });
    paths
}

/// The next unused `seg-<seq>.ftlseg` path in `dir`: max claimed
/// sequence + 1 across **all** segment-named files — including ones
/// whose content is torn or whose name does not parse as a clean `u64`
/// (see [`segment_seq`]) — so a recovered directory never re-issues a
/// sequence number that an existing file, readable or not, already
/// claims. Zero-padded to eight digits; a final existence check bumps
/// past any residual collision rather than letting the writer's rename
/// clobber a live segment.
pub fn next_segment_path(dir: &Path) -> PathBuf {
    let max = segment_paths(dir)
        .iter()
        .filter_map(|p| p.file_name().and_then(|n| n.to_str()))
        .filter_map(segment_seq)
        .max()
        .unwrap_or(0);
    let mut next = max.saturating_add(1);
    let mut path = dir.join(format!("seg-{next:08}.{SEGMENT_EXT}"));
    while path.exists() && next < u128::MAX {
        next += 1;
        path = dir.join(format!("seg-{next:08}.{SEGMENT_EXT}"));
    }
    path
}

fn entry_checksum(kind: u8, key: Fingerprint, payload: &[u8]) -> u128 {
    let mut buf = Vec::with_capacity(1 + 16 + payload.len());
    buf.push(kind);
    buf.extend_from_slice(&key.0.to_le_bytes());
    buf.extend_from_slice(payload);
    checksum(&buf).0
}

/// Seal `entries` into a new segment in `dir`. Atomic and durable: the
/// bytes go to a `.tmp-<pid>` sibling, are fsync'd, and only then
/// renamed into place (callers migrating per-entry JSON files may
/// delete them the moment this returns). Returns the final path and the
/// segment's size in bytes.
pub fn write_segment(dir: &Path, entries: &[SegmentEntry]) -> Result<(PathBuf, u64)> {
    let mut w = BinWriter::new();
    w.raw(SEG_MAGIC);
    w.str(SEGMENT_FORMAT);
    let mut index: Vec<IndexEntry> = Vec::with_capacity(entries.len());
    for e in entries {
        let offset = w.len();
        w.u8(e.kind);
        w.u128(e.key.0);
        w.u128(entry_checksum(e.kind, e.key, &e.payload));
        w.u64(e.hint);
        w.bytes(&e.payload);
        index.push(IndexEntry { kind: e.kind, key: e.key, hint: e.hint, offset, len: w.len() - offset });
    }
    let index_start = w.len();
    w.seq(&index, |w, ie| {
        w.u8(ie.kind);
        w.u128(ie.key.0);
        w.u64(ie.hint);
        w.usize(ie.offset);
        w.usize(ie.len);
    });
    let index_len = (w.len() - index_start) as u64;
    let bytes = {
        let mut buf = w.into_bytes();
        buf.extend_from_slice(&index_len.to_le_bytes());
        buf.extend_from_slice(IDX_MAGIC);
        buf
    };
    let final_path = next_segment_path(dir);
    let tmp_path = final_path.with_extension(format!("{SEGMENT_EXT}.tmp-{}", std::process::id()));
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp_path)
            .with_context(|| format!("creating segment {}", tmp_path.display()))?;
        f.write_all(&bytes).with_context(|| format!("writing segment {}", tmp_path.display()))?;
        // The durability point the JSON-migration contract rests on: old
        // per-entry files may be removed once write_segment returns.
        f.sync_all().with_context(|| format!("fsyncing segment {}", tmp_path.display()))?;
    }
    std::fs::rename(&tmp_path, &final_path)
        .with_context(|| format!("renaming {} into place", tmp_path.display()))?;
    // Best-effort directory fsync so the rename itself is durable.
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok((final_path, bytes.len() as u64))
}

/// Read and index one segment file. Never panics: a bad footer falls
/// back to a sequential entry scan (`recovered`), truncation loses only
/// the tail (`torn_tail`), and a file that is not a segment at all (or
/// carries a different codec version) is rejected as a whole.
pub fn read_segment(path: &Path) -> std::result::Result<SegmentView, SegmentError> {
    let data = std::fs::read(path).map_err(|_| SegmentError::Corrupt)?;
    if data.len() < SEG_MAGIC.len() || &data[..SEG_MAGIC.len()] != SEG_MAGIC {
        return Err(SegmentError::Corrupt);
    }
    let mut header = BinReader::new(&data[SEG_MAGIC.len()..]);
    let format = header.str().map_err(|_| SegmentError::Corrupt)?;
    if format != SEGMENT_FORMAT {
        return Err(SegmentError::Version);
    }
    let body_start = SEG_MAGIC.len() + header.position();
    // Fast path: the fixed trailer locates the footer index.
    if let Some(view) = read_via_footer(&data, body_start) {
        return Ok(SegmentView { entries: view, data, recovered: false, torn_tail: false });
    }
    // Torn/corrupt footer: recover what the entry stream still holds.
    let (entries, torn_tail) = scan_entries(&data, body_start);
    Ok(SegmentView { data, entries, recovered: true, torn_tail })
}

/// Parse the footer index; `None` means "fall back to scanning".
fn read_via_footer(data: &[u8], body_start: usize) -> Option<Vec<IndexEntry>> {
    if data.len() < body_start + TRAILER_LEN {
        return None;
    }
    let trailer = &data[data.len() - TRAILER_LEN..];
    if &trailer[8..] != IDX_MAGIC {
        return None;
    }
    let index_len = u64::from_le_bytes(trailer[..8].try_into().expect("8-byte slice")) as usize;
    let index_end = data.len() - TRAILER_LEN;
    let index_start = index_end.checked_sub(index_len)?;
    if index_start < body_start {
        return None;
    }
    let mut r = BinReader::new(&data[index_start..index_end]);
    let entries = r
        .seq(|r| {
            Ok(IndexEntry {
                kind: r.u8()?,
                key: Fingerprint(r.u128()?),
                hint: r.u64()?,
                offset: r.usize()?,
                len: r.usize()?,
            })
        })
        .ok()?;
    if !r.is_done() {
        return None;
    }
    // Every indexed range must land inside the entry region.
    let ok = entries.iter().all(|e| {
        e.offset >= body_start && e.len > 0 && e.offset.checked_add(e.len).is_some_and(|end| end <= index_start)
    });
    ok.then_some(entries)
}

/// Sequentially decode entry records from `body_start`, stopping at the
/// first undecodable byte. Returns the recovered index and whether a
/// tail was left behind (the footer of an intact segment also ends the
/// scan, but then the footer path would have been taken).
fn scan_entries(data: &[u8], body_start: usize) -> (Vec<IndexEntry>, bool) {
    let mut entries = Vec::new();
    let mut r = BinReader::new(&data[body_start..]);
    while !r.is_done() {
        let offset = body_start + r.position();
        match scan_one(&mut r) {
            Ok((kind, key, hint)) => {
                let len = body_start + r.position() - offset;
                entries.push(IndexEntry { kind, key, hint, offset, len });
            }
            Err(_) => return (entries, true),
        }
    }
    (entries, false)
}

/// Decode one entry record's framing (not its payload) at the cursor.
fn scan_one(r: &mut BinReader) -> Result<(u8, Fingerprint, u64)> {
    let kind = r.u8()?;
    if kind > 1 {
        bail!("bad entry kind {kind}");
    }
    let key = Fingerprint(r.u128()?);
    let _checksum = r.u128()?;
    let hint = r.u64()?;
    let _payload = r.bytes()?;
    Ok((kind, key, hint))
}

/// Extract and validate one entry's payload. Checks that the record's
/// own kind/fingerprint agree with the index and that the checksum over
/// kind‖fingerprint‖payload holds — the binary counterpart of the JSON
/// envelope validation.
pub fn decode_entry<'a>(data: &'a [u8], ie: &IndexEntry) -> Result<&'a [u8]> {
    let end = ie.offset.checked_add(ie.len).filter(|&e| e <= data.len());
    let Some(end) = end else { bail!("index range out of bounds") };
    let mut r = BinReader::new(&data[ie.offset..end]);
    let kind = r.u8()?;
    let key = Fingerprint(r.u128()?);
    if kind != ie.kind || key != ie.key {
        bail!("entry header disagrees with index ({} vs {})", key.hex(), ie.key.hex());
    }
    let declared = r.u128()?;
    let _hint = r.u64()?;
    let payload = r.bytes()?;
    if !r.is_done() {
        bail!("trailing bytes after entry payload");
    }
    if entry_checksum(kind, key, payload) != declared {
        bail!("entry checksum mismatch for {}", key.hex());
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ftl-segment-unit-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn entry(kind: u8, key: u128, hint: u64, payload: &[u8]) -> SegmentEntry {
        SegmentEntry { kind, key: Fingerprint(key), hint, payload: payload.to_vec() }
    }

    #[test]
    fn seals_and_reads_back_via_footer() {
        let dir = tmp_dir("roundtrip");
        let entries =
            vec![entry(0, 0xaaaa, 8, b"plan payload"), entry(1, 0xbbbb, 0, b"sim payload"), entry(0, 0xcccc, 3, b"")];
        let (path, bytes) = write_segment(&dir, &entries).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap().starts_with("seg-00000001."));
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        let view = read_segment(&path).unwrap();
        assert!(!view.recovered && !view.torn_tail);
        assert_eq!(view.entries.len(), 3);
        for (ie, e) in view.entries.iter().zip(&entries) {
            assert_eq!((ie.kind, ie.key, ie.hint), (e.kind, e.key, e.hint));
            assert_eq!(decode_entry(&view.data, ie).unwrap(), e.payload.as_slice());
        }
        // A second segment gets the next sequence number.
        let (p2, _) = write_segment(&dir, &entries[..1]).unwrap();
        assert!(p2.file_name().unwrap().to_str().unwrap().starts_with("seg-00000002."));
        assert_eq!(segment_paths(&dir), vec![path, p2]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_segment_with_unparseable_seq_never_outranks_new_appends() {
        let dir = tmp_dir("seq-safety");
        // Simulate a recovery artifact: a valid old segment restored
        // under a name whose sequence overflows u64 (2^64). Pre-fix,
        // the u64 parse silently dropped it from the max-seq scan, so
        // the next append got seg-00000001 — which sorted *before* the
        // stale file, letting its entries win every newest-wins merge.
        let scratch = tmp_dir("seq-safety-scratch");
        let (old, _) = write_segment(&scratch, &[entry(0, 0xdead, 1, b"stale payload")]).unwrap();
        let big = dir.join(format!("seg-18446744073709551616.{SEGMENT_EXT}"));
        std::fs::copy(&old, &big).unwrap();
        let (fresh, _) = write_segment(&dir, &[entry(0, 0xdead, 1, b"fresh payload")]).unwrap();
        let fresh_name = fresh.file_name().unwrap().to_str().unwrap().to_string();
        assert_eq!(fresh_name, format!("seg-18446744073709551617.{SEGMENT_EXT}"));
        // Write order per segment_paths must put the fresh append last…
        let paths = segment_paths(&dir);
        assert_eq!(paths, vec![big, fresh]);
        // …so a newest-wins replay over the directory sees the fresh payload.
        let mut live: Option<Vec<u8>> = None;
        for p in &paths {
            let view = read_segment(p).unwrap();
            for ie in &view.entries {
                if ie.key == Fingerprint(0xdead) {
                    live = Some(decode_entry(&view.data, ie).unwrap().to_vec());
                }
            }
        }
        assert_eq!(live.unwrap(), b"fresh payload");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&scratch);
    }

    #[test]
    fn unreadable_and_oddly_named_segments_still_reserve_their_seq() {
        let dir = tmp_dir("seq-reserve");
        // A torn file whose content is not even a segment still claims
        // its sequence number — the next append must not reuse it.
        std::fs::write(dir.join(format!("seg-00000007.{SEGMENT_EXT}")), b"torn garbage").unwrap();
        assert!(read_segment(&dir.join(format!("seg-00000007.{SEGMENT_EXT}"))).is_err());
        let (p, _) = write_segment(&dir, &[entry(0, 1, 0, b"x")]).unwrap();
        assert_eq!(p.file_name().unwrap().to_str().unwrap(), format!("seg-00000008.{SEGMENT_EXT}"));
        // Trailing junk after the digits (a hand-restored copy) counts too.
        std::fs::write(dir.join(format!("seg-00000042.restored.{SEGMENT_EXT}")), b"junk").unwrap();
        let next = next_segment_path(&dir);
        assert_eq!(next.file_name().unwrap().to_str().unwrap(), format!("seg-00000043.{SEGMENT_EXT}"));
        assert!(!next.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segment_order_is_numeric_across_the_zero_padding_boundary() {
        let dir = tmp_dir("seq-order");
        let a = dir.join(format!("seg-99999999.{SEGMENT_EXT}"));
        let b = dir.join(format!("seg-100000000.{SEGMENT_EXT}"));
        std::fs::write(&a, b"x").unwrap();
        std::fs::write(&b, b"y").unwrap();
        // Lexicographically "1…" < "9…", which would replay seq 10^8
        // before seq 10^8-1; the sort must be numeric.
        assert_eq!(segment_paths(&dir), vec![a, b]);
        let next = next_segment_path(&dir);
        assert_eq!(next.file_name().unwrap().to_str().unwrap(), format!("seg-100000001.{SEGMENT_EXT}"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncation_recovers_the_prefix_and_reports_the_tear() {
        let dir = tmp_dir("torn");
        let entries = vec![entry(0, 1, 5, b"first"), entry(1, 2, 4, b"second"), entry(0, 3, 3, b"third")];
        let (path, _) = write_segment(&dir, &entries).unwrap();
        let full = std::fs::read(&path).unwrap();
        let view = read_segment(&path).unwrap();
        // Cut inside the third entry: the first two must survive.
        let third = view.entries[2];
        std::fs::write(&path, &full[..third.offset + third.len / 2]).unwrap();
        let torn = read_segment(&path).unwrap();
        assert!(torn.recovered && torn.torn_tail);
        assert_eq!(torn.entries.len(), 2);
        assert_eq!(torn.entries[0].key, Fingerprint(1));
        assert_eq!(decode_entry(&torn.data, &torn.entries[1]).unwrap(), b"second");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_footer_falls_back_to_a_full_scan() {
        let dir = tmp_dir("footer");
        let entries = vec![entry(0, 7, 1, b"only")];
        let (path, _) = write_segment(&dir, &entries).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 4] ^= 0xff; // corrupt the trailer magic
        std::fs::write(&path, &bytes).unwrap();
        let view = read_segment(&path).unwrap();
        assert!(view.recovered);
        assert_eq!(view.entries.len(), 1);
        assert_eq!(decode_entry(&view.data, &view.entries[0]).unwrap(), b"only");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_catches_payload_and_key_corruption() {
        let dir = tmp_dir("checksum");
        let (path, _) = write_segment(&dir, &[entry(1, 0xfeed, 2, b"sim bytes")]).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let view = read_segment(&path).unwrap();
        let ie = view.entries[0];
        // Flip one payload byte (the last byte before the footer index).
        let mut bytes = clean.clone();
        bytes[ie.offset + ie.len - 1] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let v = read_segment(&path).unwrap();
        assert!(decode_entry(&v.data, &v.entries[0]).is_err());
        // Flip a fingerprint byte: header/index disagreement or checksum
        // failure, never a mis-keyed import.
        let mut bytes = clean;
        bytes[ie.offset + 1] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let v = read_segment(&path).unwrap();
        assert!(decode_entry(&v.data, &v.entries[0]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wrong_version_and_non_segments_are_rejected_whole() {
        let dir = tmp_dir("version");
        let (path, _) = write_segment(&dir, &[entry(0, 9, 0, b"x")]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // The header is magic (8B) + varint length (1B for a short
        // format string) + the tag itself; bump its last character so
        // the tag reads "…v9" with the wire otherwise untouched.
        let tag_end = 8 + 1 + SEGMENT_FORMAT.len() - 1;
        assert_eq!(bytes[tag_end], SEGMENT_FORMAT.as_bytes()[SEGMENT_FORMAT.len() - 1]);
        bytes[tag_end] = b'9';
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(read_segment(&path).unwrap_err(), SegmentError::Version);
        std::fs::write(&path, b"definitely not a segment").unwrap();
        assert_eq!(read_segment(&path).unwrap_err(), SegmentError::Corrupt);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_segment_round_trips() {
        let dir = tmp_dir("empty");
        let (path, _) = write_segment(&dir, &[]).unwrap();
        let view = read_segment(&path).unwrap();
        assert!(view.entries.is_empty() && !view.recovered);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
