//! Per-request tracing for the serve path.
//!
//! Every `DEPLOY` handled by the [`BatchScheduler`](super::BatchScheduler)
//! gets a monotonic **trace id** and a [`Span`]: stage timestamps
//! (admitted → queued → batch-picked → solved → simulated → reply) as
//! microsecond offsets from admission, plus the outcome, lane, warm/cold
//! flag and plan fingerprint. Completed spans land in two places:
//!
//! * a fixed-capacity **journal** (`--trace-cap`) — a ring buffer with a
//!   lock-free reservation cursor (one `fetch_add` picks the slot;
//!   individual slots are guarded by tiny mutexes, so writers never
//!   contend unless they collide on the same slot a full lap apart).
//!   `TRACE [n]` dumps the newest spans as JSON lines.
//! * a bounded **slowlog** (`--slowlog-ms`) retaining the full span of
//!   any request whose total latency exceeded the threshold — `SLOW [n]`
//!   is the "why was my p99 bad" answer.
//!
//! Served latencies are also recorded into per-lane × warm/cold
//! [`Histogram`]s plus one scheduler-wide histogram. The scheduler-wide
//! histogram is recorded *independently* at finish time, and the
//! per-lane-merge invariant — `merge(all lanes) == scheduler-wide`,
//! checked bucket-for-bucket via [`Histogram::snapshot`] — is asserted by
//! the serve self-test and a property test, so the per-lane attribution
//! provably loses no samples.
//!
//! The requester thread owns the span lifecycle: it calls
//! [`Tracer::begin`] at admission and [`Tracer::finish`] after the reply
//! arrives; the dispatcher and [`PlanService`](super::PlanService) only
//! `mark_*` stage offsets on the shared [`ActiveSpan`] in between. Stage
//! marks are clamped monotone at finish, so concurrent marking can never
//! produce a time-travelling span.

#![forbid(unsafe_code)]

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::metrics::Histogram;
use crate::util::json::Json;

use super::fingerprint::Fingerprint;

/// Stage-offset sentinel: "this stage never happened".
const UNSET: u64 = u64::MAX;

/// Tracing tunables (`--trace-cap`, `--slowlog-ms`).
#[derive(Debug, Clone)]
pub struct TraceOptions {
    /// Master switch. Disabled means the scheduler carries no tracer at
    /// all — the warm path pays zero overhead (the bench guard's
    /// baseline).
    pub enabled: bool,
    /// Journal ring-buffer capacity (spans retained for `TRACE`).
    pub journal_cap: usize,
    /// Slowlog threshold in milliseconds: a span whose total latency
    /// meets or exceeds this is retained in full for `SLOW`.
    pub slowlog_ms: u64,
    /// Max spans the slowlog retains (oldest evicted first).
    pub slowlog_cap: usize,
}

impl Default for TraceOptions {
    fn default() -> Self {
        Self { enabled: true, journal_cap: 512, slowlog_ms: 250, slowlog_cap: 64 }
    }
}

impl TraceOptions {
    /// Tracing off — the no-op baseline the overhead bench compares
    /// against.
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::default() }
    }
}

/// A request's in-flight trace: the admission instant plus atomically
/// written stage offsets (µs since admission). Shared `Arc` between the
/// requester, the dispatcher and the service; any holder may mark a
/// stage, the requester finalizes.
pub struct ActiveSpan {
    id: u64,
    start: Instant,
    queued_us: AtomicU64,
    picked_us: AtomicU64,
    solved_us: AtomicU64,
    streamed_us: AtomicU64,
    simmed_us: AtomicU64,
}

impl ActiveSpan {
    fn new(id: u64) -> Self {
        Self {
            id,
            start: Instant::now(),
            queued_us: AtomicU64::new(UNSET),
            picked_us: AtomicU64::new(UNSET),
            solved_us: AtomicU64::new(UNSET),
            streamed_us: AtomicU64::new(UNSET),
            simmed_us: AtomicU64::new(UNSET),
        }
    }

    /// The monotonic trace id (also reported in the `DEPLOY` response).
    pub fn id(&self) -> u64 {
        self.id
    }

    fn elapsed_us(&self) -> u64 {
        // UNSET is reserved as the sentinel; a >584-millennium span
        // saturating into it would be indistinguishable from "never".
        u64::try_from(self.start.elapsed().as_micros()).unwrap_or(UNSET - 1).min(UNSET - 1)
    }

    /// The request entered its lane's queue.
    pub fn mark_queued(&self) {
        self.queued_us.store(self.elapsed_us(), Ordering::Relaxed);
    }

    /// The dispatcher drained the request into a batch.
    pub fn mark_picked(&self) {
        self.picked_us.store(self.elapsed_us(), Ordering::Relaxed);
    }

    /// The plan is available (solver run or plan-cache hit).
    pub fn mark_solved(&self) {
        self.solved_us.store(self.elapsed_us(), Ordering::Relaxed);
    }

    /// The first partial reply (the `plan` event) left for the client.
    pub fn mark_streamed(&self) {
        self.streamed_us.store(self.elapsed_us(), Ordering::Relaxed);
    }

    /// The simulation report is available (engine run or sim-cache hit).
    pub fn mark_simmed(&self) {
        self.simmed_us.store(self.elapsed_us(), Ordering::Relaxed);
    }
}

/// A completed request trace. Stage fields are µs offsets from
/// admission; `None` means the stage never happened (a warm fast-path
/// hit is never queued, a shed request is never solved). Set stages are
/// monotone: `queued ≤ picked ≤ solved ≤ streamed ≤ simmed ≤ total`.
#[derive(Debug, Clone)]
pub struct Span {
    /// Monotonic trace id.
    pub id: u64,
    /// Requested workload name.
    pub workload: String,
    /// Lane index (resolve via [`Tracer::lane_name`]).
    pub lane: u32,
    /// `OK` / `SHED` / `TIMEOUT` / `ERROR`.
    pub outcome: &'static str,
    /// True iff the request was served without solver or simulator work.
    pub warm: bool,
    /// Plan fingerprint, when the request got far enough to have one.
    pub fingerprint: Option<Fingerprint>,
    /// Entered the lane queue.
    pub queued_us: Option<u64>,
    /// Drained into a batch by the dispatcher.
    pub picked_us: Option<u64>,
    /// Plan available.
    pub solved_us: Option<u64>,
    /// First partial reply (the streamed `plan` event) emitted.
    pub streamed_us: Option<u64>,
    /// Simulation report available.
    pub simmed_us: Option<u64>,
    /// Admission → reply.
    pub total_us: u64,
}

impl Span {
    /// Stage offsets in lifecycle order (set stages only) — what the
    /// monotonicity assertions walk.
    pub fn stages(&self) -> Vec<(&'static str, u64)> {
        let mut out = Vec::with_capacity(6);
        for (name, v) in [
            ("queued_us", self.queued_us),
            ("picked_us", self.picked_us),
            ("solved_us", self.solved_us),
            ("streamed_us", self.streamed_us),
            ("simmed_us", self.simmed_us),
        ] {
            if let Some(v) = v {
                out.push((name, v));
            }
        }
        out.push(("total_us", self.total_us));
        out
    }
}

/// Warm/cold served-latency histograms for one lane.
#[derive(Debug, Default)]
struct LaneHists {
    warm: Histogram,
    cold: Histogram,
}

/// Fixed-capacity span ring. The cursor is a lock-free reservation
/// (`fetch_add` picks a slot); each slot is its own mutex so a write
/// never blocks readers of other slots.
struct Journal {
    cursor: AtomicU64,
    slots: Box<[Mutex<Option<Arc<Span>>>]>,
}

impl Journal {
    fn new(cap: usize) -> Self {
        Self {
            cursor: AtomicU64::new(0),
            slots: (0..cap.max(1)).map(|_| Mutex::new(None)).collect(),
        }
    }

    fn push(&self, span: Arc<Span>) {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) % self.slots.len() as u64;
        *self.slots[i as usize].lock().expect("trace journal poisoned") = Some(span);
    }

    /// Newest-first view of up to `n` retained spans. Taken without
    /// stopping writers: a concurrent push may replace a slot mid-walk,
    /// which can surface a newer span in an older position — a telemetry
    /// view, not a linearisable cut.
    fn recent(&self, n: usize) -> Vec<Arc<Span>> {
        let total = self.cursor.load(Ordering::Relaxed);
        let cap = self.slots.len() as u64;
        let take = (n as u64).min(total.min(cap));
        let mut out = Vec::with_capacity(take as usize);
        for k in 0..take {
            let idx = ((total - 1 - k) % cap) as usize;
            if let Some(span) = self.slots[idx].lock().expect("trace journal poisoned").clone() {
                out.push(span);
            }
        }
        out
    }
}

/// The scheduler's tracing sink: trace-id allocator, span journal,
/// slowlog, and the served-latency histograms (per-lane × warm/cold plus
/// the independently recorded scheduler-wide one). See module docs.
pub struct Tracer {
    opts: TraceOptions,
    next_id: AtomicU64,
    /// Spans that reached [`Tracer::finish`]. At quiescence this equals
    /// `next_id` — the scheduler finishes every span it begins, even
    /// when the requesting connection was shed mid-flight (the front
    /// door then drops only the rendered reply). The soak harness and
    /// the shed-teardown regression test assert this end to end.
    finished: AtomicU64,
    lane_names: Vec<String>,
    journal: Journal,
    slowlog: Mutex<VecDeque<Arc<Span>>>,
    lanes: Vec<LaneHists>,
    /// All served requests, any lane, any temperature — recorded
    /// independently so the per-lane-merge invariant is a real check.
    overall: Histogram,
    /// Queue residency (`picked - queued`) of batched requests.
    queue_us: Histogram,
}

impl Tracer {
    /// New tracer for a scheduler with the given (normalized) lane names.
    pub fn new(opts: TraceOptions, lane_names: Vec<String>) -> Self {
        let journal = Journal::new(opts.journal_cap);
        let lanes = lane_names.iter().map(|_| LaneHists::default()).collect();
        Self {
            opts,
            next_id: AtomicU64::new(0),
            finished: AtomicU64::new(0),
            lane_names,
            journal,
            slowlog: Mutex::new(VecDeque::new()),
            lanes,
            overall: Histogram::new(),
            queue_us: Histogram::new(),
        }
    }

    /// The tunables this tracer runs with.
    pub fn options(&self) -> &TraceOptions {
        &self.opts
    }

    /// Start a span: allocates the next trace id and stamps admission.
    pub fn begin(&self) -> Arc<ActiveSpan> {
        Arc::new(ActiveSpan::new(self.next_id.fetch_add(1, Ordering::Relaxed) + 1))
    }

    /// Finalize a span: clamp the stage chain monotone, record served
    /// latency into the lane/warm histograms and the scheduler-wide one,
    /// journal the span, and retain it in the slowlog when over
    /// threshold. Returns the completed span.
    pub fn finish(
        &self,
        active: &ActiveSpan,
        workload: &str,
        lane: usize,
        outcome: &'static str,
        warm: bool,
        fingerprint: Option<Fingerprint>,
    ) -> Arc<Span> {
        let total_us = active.elapsed_us();
        // Monotone clamp: stage marks are written by different threads
        // off the same Instant, but a mark stored after a later stage's
        // mark could still read lower on a coarse clock.
        let mut floor = 0u64;
        let mut clamp = |raw: u64| -> Option<u64> {
            if raw == UNSET {
                return None;
            }
            floor = raw.max(floor).min(total_us);
            Some(floor)
        };
        let queued_us = clamp(active.queued_us.load(Ordering::Relaxed));
        let picked_us = clamp(active.picked_us.load(Ordering::Relaxed));
        let solved_us = clamp(active.solved_us.load(Ordering::Relaxed));
        let streamed_us = clamp(active.streamed_us.load(Ordering::Relaxed));
        let simmed_us = clamp(active.simmed_us.load(Ordering::Relaxed));
        let span = Arc::new(Span {
            id: active.id,
            workload: workload.to_string(),
            lane: lane as u32,
            outcome,
            warm,
            fingerprint,
            queued_us,
            picked_us,
            solved_us,
            streamed_us,
            simmed_us,
            total_us,
        });
        if outcome == "OK" {
            let hists = &self.lanes[lane];
            if warm { &hists.warm } else { &hists.cold }.record(total_us);
            self.overall.record(total_us);
            if let (Some(q), Some(p)) = (queued_us, picked_us) {
                self.queue_us.record(p - q);
            }
        }
        self.finished.fetch_add(1, Ordering::Relaxed);
        self.journal.push(span.clone());
        if total_us >= self.opts.slowlog_ms.saturating_mul(1000) {
            let mut slow = self.slowlog.lock().expect("slowlog poisoned");
            if slow.len() >= self.opts.slowlog_cap.max(1) {
                slow.pop_front();
            }
            slow.push_back(span.clone());
        }
        span
    }

    /// Newest-first journal dump (up to `n` spans).
    pub fn recent(&self, n: usize) -> Vec<Arc<Span>> {
        self.journal.recent(n)
    }

    /// Newest-first slowlog dump (up to `n` spans).
    pub fn slow(&self, n: usize) -> Vec<Arc<Span>> {
        let slow = self.slowlog.lock().expect("slowlog poisoned");
        slow.iter().rev().take(n).cloned().collect()
    }

    /// The lane name behind a span's lane index.
    pub fn lane_name(&self, lane: u32) -> &str {
        self.lane_names.get(lane as usize).map(String::as_str).unwrap_or("?")
    }

    /// Warm served-latency histogram of one lane.
    pub fn warm_hist(&self, lane: usize) -> &Histogram {
        &self.lanes[lane].warm
    }

    /// Cold served-latency histogram of one lane.
    pub fn cold_hist(&self, lane: usize) -> &Histogram {
        &self.lanes[lane].cold
    }

    /// The independently recorded scheduler-wide served-latency histogram.
    pub fn overall(&self) -> &Histogram {
        &self.overall
    }

    /// Queue-residency histogram (batched requests only).
    pub fn queue_hist(&self) -> &Histogram {
        &self.queue_us
    }

    /// Spans begun (trace ids issued) so far.
    pub fn spans_started(&self) -> u64 {
        self.next_id.load(Ordering::Relaxed)
    }

    /// Spans finalized so far. `spans_started == spans_finished` at
    /// quiescence — a permanently-open span is a scheduler bug (e.g. a
    /// completion lost when its connection was shed).
    pub fn spans_finished(&self) -> u64 {
        self.finished.load(Ordering::Relaxed)
    }

    /// Merge of every per-lane warm + cold histogram — by the invariant,
    /// snapshot-equal to [`overall`](Tracer::overall) when quiescent.
    pub fn merged_lanes(&self) -> Histogram {
        let merged = Histogram::new();
        for lane in &self.lanes {
            merged.merge(&lane.warm);
            merged.merge(&lane.cold);
        }
        merged
    }

    /// The `STATS` response's `latency` block: overall + queue + per-lane
    /// warm/cold histogram summaries, journal/slowlog depths, spans
    /// issued.
    pub fn latency_json(&self) -> Json {
        let lanes: std::collections::BTreeMap<String, Json> = self
            .lane_names
            .iter()
            .zip(&self.lanes)
            .map(|(name, h)| {
                (name.clone(), Json::obj(vec![("warm", h.warm.to_json()), ("cold", h.cold.to_json())]))
            })
            .collect();
        Json::obj(vec![
            ("overall", self.overall.to_json()),
            ("queue_us", self.queue_us.to_json()),
            ("lanes", Json::Obj(lanes)),
            ("spans", Json::Num(self.next_id.load(Ordering::Relaxed) as f64)),
            ("spans_finished", Json::Num(self.finished.load(Ordering::Relaxed) as f64)),
            ("journal_cap", Json::int(self.journal.slots.len())),
            ("slowlog_ms", Json::Num(self.opts.slowlog_ms as f64)),
            ("slowlog_depth", Json::int(self.slowlog.lock().expect("slowlog poisoned").len())),
        ])
    }

    /// One span as a JSON object (a `TRACE`/`SLOW` output line).
    pub fn span_json(&self, s: &Span) -> Json {
        let mut fields = vec![
            ("id", Json::Num(s.id as f64)),
            ("workload", Json::str(&s.workload)),
            ("lane", Json::str(self.lane_name(s.lane))),
            ("outcome", Json::str(s.outcome)),
            ("warm", Json::Bool(s.warm)),
        ];
        if let Some(fp) = s.fingerprint {
            fields.push(("fingerprint", Json::str(fp.hex())));
        }
        for (name, v) in s.stages() {
            fields.push((name, Json::Num(v as f64)));
        }
        Json::obj(fields)
    }

    /// Protocol rendering for `TRACE [n]` / `SLOW [n]`: a `{"spans": N}`
    /// header line followed by one JSON object per span, newest first.
    pub fn dump(&self, spans: &[Arc<Span>]) -> String {
        let mut out = Json::obj(vec![("spans", Json::int(spans.len()))]).to_string();
        for s in spans {
            out.push('\n');
            out.push_str(&self.span_json(s).to_string());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracer(opts: TraceOptions) -> Tracer {
        Tracer::new(opts, vec!["default".into(), "gold".into()])
    }

    #[test]
    fn ids_are_monotonic_and_spans_journal() {
        let t = tracer(TraceOptions::default());
        let a = t.begin();
        let b = t.begin();
        assert!(b.id() > a.id());
        t.finish(&a, "w1", 0, "OK", true, None);
        t.finish(&b, "w2", 1, "OK", false, None);
        let recent = t.recent(10);
        assert_eq!(recent.len(), 2);
        assert_eq!(recent[0].id, b.id(), "journal is newest-first");
        assert_eq!(recent[0].workload, "w2");
        assert_eq!(t.lane_name(recent[0].lane), "gold");
        assert_eq!(t.recent(1).len(), 1);
    }

    #[test]
    fn journal_ring_retains_only_cap_spans() {
        let t = tracer(TraceOptions { journal_cap: 4, ..TraceOptions::default() });
        for i in 0..10 {
            let s = t.begin();
            t.finish(&s, &format!("w{i}"), 0, "OK", true, None);
        }
        let recent = t.recent(100);
        assert_eq!(recent.len(), 4, "ring keeps the newest journal_cap spans");
        assert_eq!(recent[0].workload, "w9");
        assert_eq!(recent[3].workload, "w6");
    }

    #[test]
    fn slowlog_catches_threshold_and_caps() {
        // Threshold 0ms: everything is "slow".
        let t = tracer(TraceOptions { slowlog_ms: 0, slowlog_cap: 2, ..TraceOptions::default() });
        for i in 0..5 {
            let s = t.begin();
            t.finish(&s, &format!("s{i}"), 0, "OK", false, None);
        }
        let slow = t.slow(10);
        assert_eq!(slow.len(), 2, "slowlog is bounded");
        assert_eq!(slow[0].workload, "s4", "slowlog is newest-first");
        // A huge threshold catches nothing.
        let quiet = tracer(TraceOptions { slowlog_ms: u64::MAX, ..TraceOptions::default() });
        let s = quiet.begin();
        quiet.finish(&s, "fast", 0, "OK", true, None);
        assert!(quiet.slow(10).is_empty());
    }

    #[test]
    fn only_served_spans_record_latency() {
        let t = tracer(TraceOptions::default());
        for (outcome, warm) in [("OK", true), ("OK", false), ("SHED", false), ("TIMEOUT", false)] {
            let s = t.begin();
            t.finish(&s, "w", 0, outcome, warm, None);
        }
        assert_eq!(t.overall().count(), 2, "only OK spans are latency samples");
        assert_eq!(t.warm_hist(0).count(), 1);
        assert_eq!(t.cold_hist(0).count(), 1);
        assert_eq!(t.recent(10).len(), 4, "every span journals regardless of outcome");
    }

    #[test]
    fn merged_lanes_equals_overall() {
        let t = tracer(TraceOptions::default());
        for i in 0..50u64 {
            let s = t.begin();
            t.finish(&s, "w", (i % 2) as usize, "OK", i % 3 == 0, None);
        }
        assert_eq!(t.merged_lanes().snapshot(), t.overall().snapshot());
        assert_eq!(t.overall().count(), 50);
    }

    #[test]
    fn stage_marks_come_back_monotone() {
        let t = tracer(TraceOptions::default());
        let s = t.begin();
        // Mark out of lifecycle order; the finish clamp must restore
        // queued <= picked <= solved <= simmed <= total.
        s.mark_simmed();
        s.mark_solved();
        s.mark_picked();
        s.mark_queued();
        let span = t.finish(&s, "w", 0, "OK", false, None);
        let stages = span.stages();
        assert_eq!(stages.len(), 5);
        for pair in stages.windows(2) {
            assert!(pair[0].1 <= pair[1].1, "stage {:?} after {:?}", pair[1], pair[0]);
        }
    }

    #[test]
    fn unmarked_stages_are_absent() {
        let t = tracer(TraceOptions::default());
        let s = t.begin();
        let span = t.finish(&s, "warm-fast-path", 0, "OK", true, None);
        assert!(span.queued_us.is_none() && span.solved_us.is_none());
        assert_eq!(span.stages().len(), 1, "only total_us remains");
        let j = t.span_json(&span);
        assert!(j.get_opt("queued_us").is_none());
        assert!(j.get("total_us").is_ok());
        assert_eq!(j.get("lane").unwrap().as_str().unwrap(), "default");
    }

    #[test]
    fn dump_has_header_and_one_line_per_span() {
        let t = tracer(TraceOptions::default());
        for _ in 0..3 {
            let s = t.begin();
            t.finish(&s, "w", 0, "OK", true, None);
        }
        let text = t.dump(&t.recent(2));
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let header = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(header.get("spans").unwrap().as_usize().unwrap(), 2);
        for line in &lines[1..] {
            let j = crate::util::json::parse(line).unwrap();
            assert_eq!(j.get("outcome").unwrap().as_str().unwrap(), "OK");
        }
    }

    #[test]
    fn disabled_options_flip_only_the_switch() {
        let off = TraceOptions::disabled();
        assert!(!off.enabled);
        assert_eq!(off.journal_cap, TraceOptions::default().journal_cap);
    }
}
