//! Virtual-time weighted fair queuing — the arithmetic core under
//! [`crate::serve::lanes`].
//!
//! Start-time fair queuing over *lanes* (per-tenant queues) instead of
//! packets: each lane carries a virtual finish tag — its cumulative
//! served cost normalized by its weight — and the scheduler always
//! serves the backlogged lane with the smallest tag (ties broken by
//! lane index, so selection is a pure function of the tags). Serving a
//! quantum of cost `c` from a lane of weight `w` advances that lane's
//! tag by `c / w`; under saturation every backlogged lane's tag grows
//! at the same rate, which is exactly a weight-proportional split of
//! the served cost (a 3:1 weight ratio yields a 3:1 cost split, within
//! one quantum).
//!
//! A lane that goes idle stops accumulating tag, so a naive
//! implementation would let it *bank* credit and starve everyone else
//! on return. Instead the scheduler tracks a global virtual clock (the
//! tag of the last lane served) and, when a lane re-activates, lifts
//! its tag to `max(own tag, clock)`: an idle lane re-enters at "now",
//! keeping fairness memoryless across idle periods.
//!
//! Everything here is integer fixed-point (no floats, no `Instant`):
//! decisions are a deterministic function of the
//! (activate, pick, charge) call sequence, which is what makes the
//! fairness property tests in `rust/tests/fairness.rs` exact rather
//! than statistical.

#![forbid(unsafe_code)]

/// Fixed-point scale for virtual time: one cost unit at weight 1
/// advances a lane's tag by `SCALE`. 2^32 leaves room for
/// `cost × SCALE` in u128 at any realistic cost, and keeps the
/// rounding error of `SCALE / weight` far below one quantum.
pub const SCALE: u128 = 1 << 32;

#[derive(Debug, Clone)]
struct WfqLane {
    weight: u64,
    /// Virtual finish tag: cumulative charged cost / weight, plus any
    /// idle-period lift. Monotonically non-decreasing.
    vfinish: u128,
}

/// The virtual-time scheduler state (see module docs). Lane identity is
/// positional: callers address lanes by index into the weight vector
/// they constructed with.
#[derive(Debug, Clone)]
pub struct Wfq {
    lanes: Vec<WfqLane>,
    /// Global virtual clock: the finish tag of the most recently picked
    /// lane. Monotonically non-decreasing.
    vtime: u128,
}

impl Wfq {
    /// Scheduler over `weights.len()` lanes. Weights must be ≥ 1 (a
    /// zero weight has no meaningful finish tag; express "never serve"
    /// with a zero-capacity lane instead).
    pub fn new(weights: &[u64]) -> Self {
        assert!(!weights.is_empty(), "wfq needs at least one lane");
        assert!(weights.iter().all(|&w| w >= 1), "lane weights must be >= 1");
        Self { lanes: weights.iter().map(|&weight| WfqLane { weight, vfinish: 0 }).collect(), vtime: 0 }
    }

    /// Number of lanes.
    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    /// True when constructed over zero lanes (never — `new` asserts).
    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    /// A lane transitioned idle → backlogged: lift its tag to the
    /// global clock so the idle period earns no retroactive credit.
    /// Idempotent; calling it for an already-backlogged lane is
    /// harmless (the tag is already ≥ its own past values, and lifting
    /// to the clock again is a no-op or a legal lift).
    pub fn activate(&mut self, lane: usize) {
        let l = &mut self.lanes[lane];
        l.vfinish = l.vfinish.max(self.vtime);
    }

    /// Pick the next lane to serve among `backlogged` (indices of lanes
    /// with queued work): smallest finish tag wins, ties break to the
    /// smallest index. Advances the global clock to the winner's tag.
    /// Returns `None` when nothing is backlogged.
    pub fn pick(&mut self, backlogged: impl IntoIterator<Item = usize>) -> Option<usize> {
        let winner = backlogged.into_iter().min_by_key(|&i| (self.lanes[i].vfinish, i))?;
        self.vtime = self.vtime.max(self.lanes[winner].vfinish);
        Some(winner)
    }

    /// Account `cost` units of served work to `lane`: its tag advances
    /// by `cost / weight` (in [`SCALE`] fixed point). Zero cost is a
    /// no-op — a quantum that turned out to be all-warm consumed none
    /// of the budget fairness is defined over.
    pub fn charge(&mut self, lane: usize, cost: u64) {
        let l = &mut self.lanes[lane];
        l.vfinish += cost as u128 * SCALE / l.weight as u128;
    }

    /// A lane's virtual finish tag (monotone; see module docs).
    pub fn vfinish(&self, lane: usize) -> u128 {
        self.lanes[lane].vfinish
    }

    /// The global virtual clock (monotone).
    pub fn vtime(&self) -> u128 {
        self.vtime
    }

    /// A lane's configured weight.
    pub fn weight(&self, lane: usize) -> u64 {
        self.lanes[lane].weight
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serve `rounds` unit-cost quanta with every lane permanently
    /// backlogged; return the per-lane served counts.
    fn saturate(weights: &[u64], rounds: usize) -> Vec<u64> {
        let mut wfq = Wfq::new(weights);
        let mut served = vec![0u64; weights.len()];
        for _ in 0..rounds {
            let lane = wfq.pick(0..weights.len()).unwrap();
            wfq.charge(lane, 1);
            served[lane] += 1;
        }
        served
    }

    #[test]
    fn three_to_one_split_is_exact_over_whole_periods() {
        // 16 unit quanta at 3:1 must split 12:4 — the acceptance
        // criterion's share, with zero tolerance needed.
        assert_eq!(saturate(&[3, 1], 16), vec![12, 4]);
        assert_eq!(saturate(&[1, 3], 16), vec![4, 12]);
        assert_eq!(saturate(&[1, 1], 16), vec![8, 8]);
    }

    #[test]
    fn shares_track_weights_within_one_quantum() {
        let weights = [5u64, 2, 1];
        let total: u64 = weights.iter().sum();
        for rounds in [7usize, 40, 161] {
            let served = saturate(&weights, rounds);
            for (i, &w) in weights.iter().enumerate() {
                let expected = rounds as f64 * w as f64 / total as f64;
                let dev = (served[i] as f64 - expected).abs();
                assert!(dev <= 1.0 + 1e-9, "lane {i} served {} vs expected {expected:.2} over {rounds}", served[i]);
            }
        }
    }

    #[test]
    fn idle_lane_reenters_at_the_clock_not_at_zero() {
        let mut wfq = Wfq::new(&[1, 1]);
        // Lane 1 idles while lane 0 is served 100 quanta.
        for _ in 0..100 {
            let lane = wfq.pick([0]).unwrap();
            wfq.charge(lane, 1);
        }
        // Lane 1 wakes up: without the activate lift it would win the
        // next 100 picks in a row; with it, service alternates.
        wfq.activate(1);
        let mut lane1_streak = 0u32;
        for _ in 0..10 {
            let lane = wfq.pick(0..2).unwrap();
            wfq.charge(lane, 1);
            if lane == 1 {
                lane1_streak += 1;
            } else {
                break;
            }
        }
        assert!(lane1_streak <= 1, "an idle lane must not bank credit (got a {lane1_streak}-long burst)");
    }

    #[test]
    fn tags_and_clock_are_monotone() {
        let mut wfq = Wfq::new(&[3, 1, 2]);
        let mut last_tags: Vec<u128> = (0..3).map(|i| wfq.vfinish(i)).collect();
        let mut last_clock = wfq.vtime();
        for step in 0..200usize {
            let lane = wfq.pick(0..3).unwrap();
            wfq.charge(lane, 1 + (step % 4) as u64);
            if step % 7 == 0 {
                wfq.activate(step % 3);
            }
            for (i, last) in last_tags.iter_mut().enumerate() {
                assert!(wfq.vfinish(i) >= *last, "lane {i} tag regressed at step {step}");
                *last = wfq.vfinish(i);
            }
            assert!(wfq.vtime() >= last_clock, "clock regressed at step {step}");
            last_clock = wfq.vtime();
        }
    }

    #[test]
    fn ties_break_by_lane_index() {
        let mut wfq = Wfq::new(&[1, 1]);
        assert_eq!(wfq.pick(0..2), Some(0), "equal tags must pick the lowest index");
        assert_eq!(wfq.pick([1, 0]), Some(0), "iteration order must not matter");
    }

    #[test]
    fn zero_cost_charges_are_free() {
        let mut wfq = Wfq::new(&[2, 1]);
        let before = wfq.vfinish(0);
        wfq.charge(0, 0);
        assert_eq!(wfq.vfinish(0), before);
    }

    #[test]
    fn empty_backlog_picks_nothing() {
        let mut wfq = Wfq::new(&[1]);
        assert_eq!(wfq.pick(std::iter::empty()), None);
    }

    #[test]
    #[should_panic(expected = "weights must be >= 1")]
    fn zero_weight_rejected() {
        Wfq::new(&[1, 0]);
    }
}
