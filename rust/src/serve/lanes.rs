//! Named priority lanes for the batch scheduler.
//!
//! A *lane* is a tenant-visible traffic class: its own bounded FIFO
//! queue, an integer weight, and (optionally) its own full-queue
//! admission policy. The [`LaneSet`] bundles the per-lane queues with a
//! [`Wfq`] scheduler so the dispatcher's quantum loop is one line each:
//! `pick()` the lane whose virtual finish tag is smallest, `drain()` a
//! batch from it, `charge()` the cold work it cost. Under saturation
//! that yields a weight-proportional split of cold work across lanes
//! (see the `wfq` module docs for the arithmetic and the no-banked-
//! credit rule).
//!
//! [`LaneSet`] is deliberately pure data — no threads, no clocks, no
//! counters: the batch scheduler drives it under its queue mutex with
//! real traffic, and the fairness property tests
//! (`rust/tests/fairness.rs`) drive the very same type with a virtual
//! clock and synthetic costs, so the fairness bound is asserted on the
//! exact code that schedules production batches.
//!
//! Requests name lanes by string; an unknown or absent lane name
//! resolves to the [`DEFAULT_LANE`], which always exists
//! ([`normalize_specs`] prepends it when the configuration does not
//! define one). A single default lane of weight 1 reproduces the
//! pre-lane single-FIFO scheduler bit-for-bit — that degenerate
//! configuration is pinned by regression tests.

#![forbid(unsafe_code)]

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::metrics::Counter;

use super::batch::AdmissionPolicy;
use super::wfq::Wfq;

/// Name of the lane that absent/unknown lane references resolve to.
pub const DEFAULT_LANE: &str = "default";

/// Configuration of one priority lane.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneSpec {
    /// Lane name — the `lane=` vocabulary of the line protocol.
    pub name: String,
    /// WFQ weight (≥ 1): under saturation, lanes split cold work in
    /// proportion to their weights.
    pub weight: u64,
    /// Bounded-queue capacity. **Zero admits nothing** — every request
    /// aimed at the lane is shed (same contract as a zero-capacity
    /// scheduler queue).
    pub capacity: usize,
    /// Full-queue policy override; `None` inherits the scheduler-wide
    /// policy ([`crate::serve::BatchOptions::policy`]).
    pub policy: Option<AdmissionPolicy>,
    /// Default deadline applied to requests admitted into this lane
    /// that carry none of their own; `None` leaves such requests
    /// unbounded. A client-supplied deadline always wins.
    pub default_deadline: Option<std::time::Duration>,
}

impl LaneSpec {
    /// Lane with the scheduler-default admission policy.
    pub fn new(name: impl Into<String>, weight: u64, capacity: usize) -> Self {
        Self { name: name.into(), weight, capacity, policy: None, default_deadline: None }
    }

    /// Parse the CLI form `name:weight:capacity[:shed|:block][:deadline-ms]`
    /// (the repeatable `ftl serve --lane` flag). The optional fourth
    /// token is a policy when it says `shed`/`block` and a default
    /// deadline when it parses as an integer; both may be given, policy
    /// first.
    pub fn parse(spec: &str) -> Result<Self> {
        let parts: Vec<&str> = spec.split(':').collect();
        let parse_policy = |policy: &str| -> Result<AdmissionPolicy> {
            match policy {
                "shed" => Ok(AdmissionPolicy::Shed),
                "block" => Ok(AdmissionPolicy::Block),
                other => bail!("bad lane policy '{other}' in '{spec}' (expected shed|block)"),
            }
        };
        let (name, weight, capacity, policy, deadline_ms) = match parts.as_slice() {
            [name, weight, cap] => (*name, *weight, *cap, None, None),
            [name, weight, cap, tail] => match tail.parse::<u64>() {
                Ok(ms) => (*name, *weight, *cap, None, Some(ms)),
                Err(_) => (*name, *weight, *cap, Some(parse_policy(tail)?), None),
            },
            [name, weight, cap, policy, deadline] => {
                let ms: u64 = deadline
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad lane deadline '{deadline}' in '{spec}' (expected milliseconds)"))?;
                (*name, *weight, *cap, Some(parse_policy(policy)?), Some(ms))
            }
            _ => bail!("bad lane spec '{spec}' (expected name:weight:capacity[:shed|:block][:deadline-ms])"),
        };
        if name.is_empty() || name.contains(char::is_whitespace) {
            bail!("bad lane name in '{spec}' (must be non-empty, no whitespace)");
        }
        let weight: u64 = weight.parse().map_err(|_| anyhow::anyhow!("bad lane weight in '{spec}'"))?;
        if weight == 0 {
            bail!("lane weight must be >= 1 in '{spec}' (use capacity 0 to disable a lane)");
        }
        let capacity: usize = capacity.parse().map_err(|_| anyhow::anyhow!("bad lane capacity in '{spec}'"))?;
        Ok(Self {
            name: name.to_string(),
            weight,
            capacity,
            policy,
            default_deadline: deadline_ms.map(std::time::Duration::from_millis),
        })
    }
}

/// Validate a lane configuration and guarantee the [`DEFAULT_LANE`]
/// exists: an empty list becomes a single default lane of weight 1 and
/// capacity `default_capacity` (the pre-lane scheduler, exactly); a
/// list without a `default` lane gets one prepended. Duplicate names
/// and zero weights are errors.
pub fn normalize_specs(mut specs: Vec<LaneSpec>, default_capacity: usize) -> Result<Vec<LaneSpec>> {
    if !specs.iter().any(|s| s.name == DEFAULT_LANE) {
        specs.insert(0, LaneSpec::new(DEFAULT_LANE, 1, default_capacity));
    }
    let mut seen = std::collections::BTreeSet::new();
    for s in &specs {
        if s.weight == 0 {
            bail!("lane '{}' has weight 0 (must be >= 1)", s.name);
        }
        if !seen.insert(s.name.as_str()) {
            bail!("duplicate lane name '{}'", s.name);
        }
    }
    Ok(specs)
}

/// Resolve a lane name against a spec list: `None` and unknown names go
/// to the default lane — the single implementation behind
/// [`LaneSet::resolve`] and the scheduler's lock-free name resolution.
pub(crate) fn resolve_lane(specs: &[LaneSpec], default_lane: usize, name: Option<&str>) -> usize {
    match name {
        None => default_lane,
        Some(n) => specs.iter().position(|s| s.name == n).unwrap_or(default_lane),
    }
}

/// Per-lane queues + WFQ state (see module docs). `T` is the queued
/// request type — [`crate::serve::BatchScheduler`] queues its pending
/// requests, the fairness tests queue synthetic jobs.
#[derive(Debug, Clone)]
pub struct LaneSet<T> {
    specs: Vec<LaneSpec>,
    default_lane: usize,
    queues: Vec<VecDeque<T>>,
    wfq: Wfq,
}

impl<T> LaneSet<T> {
    /// Build from lane specs; panics on an invalid set (duplicates,
    /// zero weights) — construction-time configuration errors, not
    /// runtime conditions. A missing default lane is added with
    /// **unbounded** capacity (the pure-harness convenience); callers
    /// that want the default lane bounded by a real queue capacity (the
    /// batch scheduler does) must run [`normalize_specs`] with that
    /// capacity first.
    pub fn new(specs: Vec<LaneSpec>) -> Self {
        let specs = normalize_specs(specs, usize::MAX).expect("invalid lane configuration");
        let default_lane = specs.iter().position(|s| s.name == DEFAULT_LANE).expect("default lane exists");
        let weights: Vec<u64> = specs.iter().map(|s| s.weight).collect();
        let queues = specs.iter().map(|_| VecDeque::new()).collect();
        Self { specs, default_lane, queues, wfq: Wfq::new(&weights) }
    }

    /// Number of lanes.
    pub fn num_lanes(&self) -> usize {
        self.specs.len()
    }

    /// The lane configurations, in index order.
    pub fn specs(&self) -> &[LaneSpec] {
        &self.specs
    }

    /// Index of the [`DEFAULT_LANE`].
    pub fn default_lane(&self) -> usize {
        self.default_lane
    }

    /// Resolve a request's lane name: `None` and unknown names go to
    /// the default lane (the protocol's "unknown lane → default lane").
    pub fn resolve(&self, name: Option<&str>) -> usize {
        resolve_lane(&self.specs, self.default_lane, name)
    }

    /// Queue depth of one lane.
    pub fn len_of(&self, lane: usize) -> usize {
        self.queues[lane].len()
    }

    /// Total queued across all lanes.
    pub fn total_len(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    /// Depth of the fullest lane (the batch-window early-exit test:
    /// with a single lane this is exactly the old queue length).
    pub fn max_len(&self) -> usize {
        self.queues.iter().map(VecDeque::len).max().unwrap_or(0)
    }

    /// True when no lane has queued work.
    pub fn is_all_empty(&self) -> bool {
        self.queues.iter().all(VecDeque::is_empty)
    }

    /// Bounded enqueue: hands the item back via `Err` when the lane is
    /// at capacity (always, for a zero-capacity lane). An
    /// empty→backlogged transition lifts the lane's WFQ tag to the
    /// clock (no banked idle credit).
    pub fn try_push(&mut self, lane: usize, item: T) -> Result<(), T> {
        let spec = &self.specs[lane];
        if self.queues[lane].len() >= spec.capacity {
            return Err(item);
        }
        if self.queues[lane].is_empty() {
            self.wfq.activate(lane);
        }
        self.queues[lane].push_back(item);
        Ok(())
    }

    /// WFQ-pick the next lane to serve among the backlogged lanes;
    /// `None` when everything is empty. Deterministic: smallest virtual
    /// finish tag, ties to the smallest lane index.
    pub fn pick(&mut self) -> Option<usize> {
        // Destructure so the backlog iterator (borrowing `queues`) can
        // feed `wfq.pick` (borrowing `wfq` mutably) without a Vec
        // round-trip — this runs once per quantum under the scheduler's
        // queue mutex.
        let Self { queues, wfq, .. } = self;
        wfq.pick((0..queues.len()).filter(|&i| !queues[i].is_empty()))
    }

    /// Dequeue up to `max` items from one lane, FIFO order.
    pub fn drain(&mut self, lane: usize, max: usize) -> Vec<T> {
        let n = self.queues[lane].len().min(max);
        self.queues[lane].drain(..n).collect()
    }

    /// Account served cold work to a lane (advances its WFQ tag).
    pub fn charge(&mut self, lane: usize, cost: u64) {
        self.wfq.charge(lane, cost);
    }

    /// A lane's virtual finish tag (fixed point, monotone — see
    /// [`crate::serve::wfq`]).
    pub fn vfinish(&self, lane: usize) -> u128 {
        self.wfq.vfinish(lane)
    }
}

/// Monotonic per-lane counters, updated lock-free by the scheduler and
/// snapshotted into [`crate::metrics::LaneStats`]. The scheduler-wide
/// `batch.*` totals are *derived* as sums over these, so the invariant
/// `sum(lanes.*.shed) == batch.shed` (and likewise for every counter)
/// holds by construction — and is still invariant-tested, so it cannot
/// silently rot if the derivation changes. All fields are saturating
/// [`Counter`]s: a long-lived replica pins at `u64::MAX` instead of
/// wrapping.
#[derive(Debug, Default)]
pub struct LaneCounters {
    /// Batches dispatched from this lane (one WFQ quantum each).
    pub batches: Counter,
    /// Requests dispatched through this lane's batches.
    pub batched_requests: Counter,
    /// Largest single batch dispatched from this lane.
    pub max_batch_size: Counter,
    /// Requests shed by admission control at this lane.
    pub shed: Counter,
    /// Requests whose deadline expired while owned by this lane.
    pub timeouts: Counter,
    /// Requests answered with a served reply from this lane's batches.
    pub served: Counter,
    /// Cold-work units charged to this lane (cache-miss solves its
    /// batches paid for — the quantity WFQ fairness is defined over).
    pub cold_work: Counter,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_forms() {
        let l = LaneSpec::parse("gold:3:64").unwrap();
        assert_eq!((l.name.as_str(), l.weight, l.capacity, l.policy), ("gold", 3, 64, None));
        let l = LaneSpec::parse("free:1:16:shed").unwrap();
        assert_eq!(l.policy, Some(AdmissionPolicy::Shed));
        let l = LaneSpec::parse("bulk:2:0:block").unwrap();
        assert_eq!((l.capacity, l.policy), (0, Some(AdmissionPolicy::Block)));
    }

    #[test]
    fn parse_accepts_default_deadlines() {
        let l = LaneSpec::parse("free:1:16:250").unwrap();
        assert_eq!(l.policy, None);
        assert_eq!(l.default_deadline, Some(std::time::Duration::from_millis(250)));
        let l = LaneSpec::parse("free:1:16:shed:250").unwrap();
        assert_eq!(l.policy, Some(AdmissionPolicy::Shed));
        assert_eq!(l.default_deadline, Some(std::time::Duration::from_millis(250)));
        let l = LaneSpec::parse("gold:3:64").unwrap();
        assert_eq!(l.default_deadline, None);
        for bad in ["free:1:16:250:shed", "free:1:16:shed:fast", "free:1:16:shed:250:extra"] {
            assert!(LaneSpec::parse(bad).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in ["", "gold", "gold:3", "gold:3:64:fifo", ":3:64", "gold:0:64", "gold:x:64", "gold:3:y", "a b:1:4"] {
            assert!(LaneSpec::parse(bad).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn normalize_prepends_default_and_rejects_duplicates() {
        let specs = normalize_specs(vec![LaneSpec::new("gold", 3, 8)], 256).unwrap();
        assert_eq!(specs[0].name, DEFAULT_LANE);
        assert_eq!((specs[0].weight, specs[0].capacity), (1, 256));
        assert_eq!(specs[1].name, "gold");

        let specs = normalize_specs(vec![LaneSpec::new(DEFAULT_LANE, 2, 4)], 256).unwrap();
        assert_eq!(specs.len(), 1, "an explicit default lane must be kept, not doubled");
        assert_eq!(specs[0].weight, 2);

        assert!(normalize_specs(vec![LaneSpec::new("a", 1, 4), LaneSpec::new("a", 2, 4)], 16).is_err());
        let zero_weight = LaneSpec { name: "z".into(), weight: 0, capacity: 4, policy: None, default_deadline: None };
        assert!(normalize_specs(vec![zero_weight], 16).is_err());
    }

    #[test]
    fn resolve_falls_back_to_default() {
        let lanes: LaneSet<u32> = LaneSet::new(vec![LaneSpec::new("gold", 3, 8), LaneSpec::new("free", 1, 8)]);
        assert_eq!(lanes.specs()[lanes.default_lane()].name, DEFAULT_LANE);
        assert_eq!(lanes.resolve(Some("gold")), 1);
        assert_eq!(lanes.resolve(Some("no-such-lane")), lanes.default_lane());
        assert_eq!(lanes.resolve(None), lanes.default_lane());
    }

    #[test]
    fn try_push_honours_capacity_and_zero_cap_admits_nothing() {
        let mut lanes: LaneSet<u32> = LaneSet::new(vec![LaneSpec::new("tiny", 1, 2), LaneSpec::new("off", 1, 0)]);
        let tiny = lanes.resolve(Some("tiny"));
        let off = lanes.resolve(Some("off"));
        assert!(lanes.try_push(tiny, 1).is_ok());
        assert!(lanes.try_push(tiny, 2).is_ok());
        assert_eq!(lanes.try_push(tiny, 3), Err(3), "third push must bounce off capacity 2");
        assert_eq!(lanes.try_push(off, 1), Err(1), "zero-capacity lane admits nothing");
        assert_eq!(lanes.len_of(tiny), 2);
        assert_eq!(lanes.total_len(), 2);
        assert_eq!(lanes.max_len(), 2);
    }

    #[test]
    fn drain_is_fifo_within_a_lane() {
        let mut lanes: LaneSet<u32> = LaneSet::new(vec![]);
        let d = lanes.default_lane();
        for v in [10, 11, 12] {
            assert!(lanes.try_push(d, v).is_ok());
        }
        assert_eq!(lanes.pick(), Some(d));
        assert_eq!(lanes.drain(d, 2), vec![10, 11]);
        assert_eq!(lanes.drain(d, 8), vec![12]);
        assert!(lanes.is_all_empty());
        assert_eq!(lanes.pick(), None);
    }

    #[test]
    fn single_default_lane_degenerates_to_fifo() {
        // The degenerate configuration behind the FIFO-equivalence
        // regression suite: one lane, every pick returns it, drain
        // order is arrival order.
        let mut lanes: LaneSet<u32> = LaneSet::new(vec![]);
        assert_eq!(lanes.num_lanes(), 1);
        let d = lanes.default_lane();
        for v in 0..5 {
            assert!(lanes.try_push(d, v).is_ok());
        }
        let mut out = Vec::new();
        while let Some(lane) = lanes.pick() {
            assert_eq!(lane, d);
            out.extend(lanes.drain(lane, 2));
            lanes.charge(lane, 1);
        }
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }
}
