//! Sharded, capacity-bounded LRU cache of compiled deployment plans.
//!
//! Keys are [`Fingerprint`]s; values are cheap-to-clone handles (the serve
//! layer stores `Arc<Deployment>`, so a hit shares the plan instead of
//! copying it). Shards each hold an independent `Mutex`, so concurrent
//! requests for *different* plans never contend on one lock; recency is a
//! global monotonic tick, cheap to bump and good enough for an
//! eviction-order LRU. Hit/miss/eviction/insert counters aggregate into a
//! [`crate::metrics::CacheStats`] snapshot for reports; they are
//! saturating [`Counter`]s, so a long-lived replica pins at `u64::MAX`
//! instead of wrapping. (The recency `tick` stays a plain wrapping
//! `AtomicU64` on purpose: saturating it would freeze LRU ordering.)

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::metrics::{CacheStats, Counter};

use super::fingerprint::Fingerprint;

struct Entry<V> {
    value: V,
    last_used: u64,
    /// Lane-weight hint: the WFQ weight of the lane that last hit this
    /// entry (0 = never hit through a lane). Persisted with snapshots so
    /// warm-start can load premium tenants' plans first — see
    /// [`crate::serve::persist`].
    hint: u64,
}

struct Shard<V> {
    map: HashMap<u128, Entry<V>>,
}

/// A sharded LRU keyed by [`Fingerprint`] (generic so the eviction logic
/// is unit-testable with plain values; the serve layer instantiates it as
/// [`PlanCache`]).
pub struct LruCache<V: Clone> {
    shards: Vec<Mutex<Shard<V>>>,
    /// Max entries per shard (total capacity is spread over the shards).
    per_shard: usize,
    capacity: usize,
    tick: AtomicU64,
    hits: Counter,
    misses: Counter,
    evictions: Counter,
    inserts: Counter,
}

/// The serve layer's plan cache.
pub type PlanCache = LruCache<std::sync::Arc<crate::coordinator::Deployment>>;

/// The serve layer's simulation-report cache: keyed by the plan
/// fingerprint rehashed under a sim domain tag (see
/// [`super::fingerprint::Fingerprint::derive`]), so warm requests skip
/// `sim::engine` entirely.
pub type SimCache = LruCache<std::sync::Arc<crate::sim::SimReport>>;

impl<V: Clone> LruCache<V> {
    /// New cache holding at most `capacity` entries spread over `shards`
    /// lock domains. `shards` is clamped to `>= 1`; per-shard capacity is
    /// rounded up so the total is never *below* the requested capacity.
    pub fn new(capacity: usize, shards: usize) -> Self {
        let shards = shards.clamp(1, capacity.max(1));
        let per_shard = capacity.div_ceil(shards);
        let shards_vec = (0..shards).map(|_| Mutex::new(Shard { map: HashMap::new() })).collect();
        Self {
            shards: shards_vec,
            per_shard,
            capacity,
            tick: AtomicU64::new(0),
            hits: Counter::new(0),
            misses: Counter::new(0),
            evictions: Counter::new(0),
            inserts: Counter::new(0),
        }
    }

    fn shard(&self, key: Fingerprint) -> &Mutex<Shard<V>> {
        &self.shards[key.shard(self.shards.len())]
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Look up a plan; bumps recency and the hit/miss counters.
    pub fn get(&self, key: Fingerprint) -> Option<V> {
        match self.lookup(key) {
            Some(v) => {
                self.hits.inc();
                Some(v)
            }
            None => {
                self.misses.inc();
                None
            }
        }
    }

    /// Look up without touching the hit/miss counters (recency still
    /// bumps). For internal double-checks — e.g. re-probing inside a
    /// single-flight after a counted miss — so one request never counts
    /// two misses.
    pub fn get_quiet(&self, key: Fingerprint) -> Option<V> {
        self.lookup(key)
    }

    fn lookup(&self, key: Fingerprint) -> Option<V> {
        let mut shard = self.shard(key).lock().expect("plan-cache shard poisoned");
        let entry = shard.map.get_mut(&key.0)?;
        entry.last_used = self.next_tick();
        Some(entry.value.clone())
    }

    /// Insert (or refresh) a plan, evicting least-recently-used entries
    /// from the key's shard if it would exceed its capacity share.
    pub fn insert(&self, key: Fingerprint, value: V) {
        if self.capacity == 0 {
            return;
        }
        let tick = self.next_tick();
        let mut shard = self.shard(key).lock().expect("plan-cache shard poisoned");
        // A refresh keeps the lane hint: re-solving a plan does not
        // change who is hitting it.
        let hint = shard.map.get(&key.0).map_or(0, |e| e.hint);
        // A refresh of an existing key is not an insert: `inserts -
        // evictions` must keep tracking `entries` or persisted-snapshot
        // accounting drifts.
        if shard.map.insert(key.0, Entry { value, last_used: tick, hint }).is_none() {
            self.inserts.inc();
        }
        while shard.map.len() > self.per_shard {
            let oldest = shard
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k)
                .expect("non-empty shard has an LRU entry");
            shard.map.remove(&oldest);
            self.evictions.inc();
        }
    }

    /// Whether a key is currently cached (does not bump recency/counters).
    pub fn contains(&self, key: Fingerprint) -> bool {
        self.shard(key).lock().expect("plan-cache shard poisoned").map.contains_key(&key.0)
    }

    /// Raise the lane-weight hint of a cached entry (no-op on a miss;
    /// no recency/counter side effects). Hints only ratchet upward so a
    /// plan shared by a premium and a bulk lane keeps its premium
    /// warm-up priority.
    pub fn raise_hint(&self, key: Fingerprint, hint: u64) {
        let mut shard = self.shard(key).lock().expect("plan-cache shard poisoned");
        if let Some(e) = shard.map.get_mut(&key.0) {
            e.hint = e.hint.max(hint);
        }
    }

    /// [`Self::insert`] with an initial lane-weight hint — the snapshot
    /// loader's import path (the hint from the segment index survives
    /// the restart). An existing entry keeps the larger hint.
    pub fn insert_hinted(&self, key: Fingerprint, value: V, hint: u64) {
        self.insert(key, value);
        self.raise_hint(key, hint);
    }

    /// Snapshot every cached entry (no recency/counter side effects) —
    /// the export hook of the persistence layer ([`crate::serve::persist`]).
    /// Keys come out sorted so snapshot writes are deterministic.
    pub fn export(&self) -> Vec<(Fingerprint, V)> {
        let mut entries: Vec<(Fingerprint, V)> = self
            .shards
            .iter()
            .flat_map(|s| {
                let shard = s.lock().expect("plan-cache shard poisoned");
                shard.map.iter().map(|(&k, e)| (Fingerprint(k), e.value.clone())).collect::<Vec<_>>()
            })
            .collect();
        entries.sort_by_key(|(k, _)| *k);
        entries
    }

    /// [`Self::export`] including each entry's lane-weight hint — what
    /// the snapshot writer persists into the segment index so warm-start
    /// can order loads heaviest-lane-first.
    pub fn export_hinted(&self) -> Vec<(Fingerprint, V, u64)> {
        let mut entries: Vec<(Fingerprint, V, u64)> = self
            .shards
            .iter()
            .flat_map(|s| {
                let shard = s.lock().expect("plan-cache shard poisoned");
                shard.map.iter().map(|(&k, e)| (Fingerprint(k), e.value.clone(), e.hint)).collect::<Vec<_>>()
            })
            .collect();
        entries.sort_by_key(|(k, _, _)| *k);
        entries
    }

    /// Current number of cached plans across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().expect("plan-cache shard poisoned").map.len()).sum()
    }

    /// True if no plans are cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter snapshot for reports.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            evictions: self.evictions.get(),
            inserts: self.inserts.get(),
            entries: self.len(),
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(v: u128) -> Fingerprint {
        Fingerprint(v)
    }

    #[test]
    fn hit_miss_counters() {
        let c: LruCache<u32> = LruCache::new(4, 1);
        assert!(c.get(key(1)).is_none());
        c.insert(key(1), 10);
        assert_eq!(c.get(key(1)), Some(10));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.inserts), (1, 1, 1));
        assert_eq!(s.entries, 1);
        assert_eq!(s.capacity, 4);
    }

    #[test]
    fn evicts_least_recently_used() {
        let c: LruCache<u32> = LruCache::new(3, 1);
        c.insert(key(1), 1);
        c.insert(key(2), 2);
        c.insert(key(3), 3);
        // Touch 1 so 2 becomes the LRU entry.
        assert_eq!(c.get(key(1)), Some(1));
        c.insert(key(4), 4);
        assert_eq!(c.len(), 3);
        assert!(c.contains(key(1)), "recently-used entry must survive");
        assert!(!c.contains(key(2)), "LRU entry must be evicted");
        assert!(c.contains(key(3)));
        assert!(c.contains(key(4)));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn eviction_order_is_lru_not_fifo() {
        let c: LruCache<u32> = LruCache::new(2, 1);
        c.insert(key(1), 1);
        c.insert(key(2), 2);
        assert_eq!(c.get(key(1)), Some(1)); // 1 is now newer than 2
        c.insert(key(3), 3);
        assert!(c.contains(key(1)));
        assert!(!c.contains(key(2)));
    }

    #[test]
    fn quiet_lookup_skips_counters_but_bumps_recency() {
        let c: LruCache<u32> = LruCache::new(2, 1);
        c.insert(key(1), 1);
        c.insert(key(2), 2);
        assert_eq!(c.get_quiet(key(1)), Some(1));
        assert_eq!(c.get_quiet(key(9)), None);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 0), "quiet lookups must not count");
        // The quiet touch of 1 made 2 the LRU entry.
        c.insert(key(3), 3);
        assert!(c.contains(key(1)));
        assert!(!c.contains(key(2)));
    }

    #[test]
    fn refresh_does_not_count_as_insert() {
        let c: LruCache<u32> = LruCache::new(4, 1);
        c.insert(key(1), 10);
        c.insert(key(1), 11); // refresh: value replaced, not a new entry
        c.insert(key(2), 20);
        assert_eq!(c.get(key(1)), Some(11), "refresh must keep the newest value");
        let s = c.stats();
        assert_eq!(s.inserts, 2, "refreshing an existing key must not bump inserts");
        assert_eq!(s.entries, 2);
        assert_eq!(s.inserts - s.evictions, s.entries as u64, "inserts - evictions must track entries");
    }

    #[test]
    fn insert_eviction_invariant_holds_under_churn() {
        let c: LruCache<u32> = LruCache::new(3, 1);
        for i in 0..32u128 {
            c.insert(key(i % 7), i as u32); // refreshes and evictions interleave
            let s = c.stats();
            assert_eq!(
                s.inserts - s.evictions,
                s.entries as u64,
                "invariant broke at step {i}: inserts={} evictions={} entries={}",
                s.inserts,
                s.evictions,
                s.entries
            );
        }
    }

    #[test]
    fn export_snapshots_all_entries_without_side_effects() {
        let c: LruCache<u32> = LruCache::new(8, 4);
        for i in 0..5u128 {
            c.insert(key(i << 64 | i), i as u32);
        }
        let before = c.stats();
        let mut exported = c.export();
        exported.sort_by_key(|(k, _)| k.0);
        assert_eq!(exported.len(), 5);
        assert_eq!(exported.iter().map(|&(_, v)| v).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        let after = c.stats();
        assert_eq!((before.hits, before.misses, before.inserts), (after.hits, after.misses, after.inserts));
    }

    #[test]
    fn lane_hints_ratchet_and_survive_refresh() {
        let c: LruCache<u32> = LruCache::new(4, 1);
        c.insert(key(1), 10);
        c.raise_hint(key(1), 8);
        c.raise_hint(key(1), 3); // lower hint must not clobber
        c.raise_hint(key(9), 5); // miss: silently ignored
        c.insert(key(1), 11); // refresh keeps the hint
        c.insert_hinted(key(2), 20, 2);
        let hinted = c.export_hinted();
        assert_eq!(hinted.len(), 2);
        assert_eq!(hinted.iter().map(|&(k, v, h)| (k.0, v, h)).collect::<Vec<_>>(), vec![(1, 11, 8), (2, 20, 2)]);
        // Plain export is unchanged by hints.
        assert_eq!(c.export().len(), 2);
    }

    #[test]
    fn zero_capacity_never_stores() {
        let c: LruCache<u32> = LruCache::new(0, 4);
        c.insert(key(1), 1);
        assert!(c.is_empty());
        assert!(c.get(key(1)).is_none());
    }

    #[test]
    fn sharding_spreads_but_total_capacity_holds() {
        let c: LruCache<u32> = LruCache::new(8, 4);
        for i in 0..64u128 {
            c.insert(key(i << 64 | i), i as u32); // vary the shard bits
        }
        assert!(c.len() <= 8, "len {} exceeds capacity", c.len());
        assert!(c.stats().evictions >= 56);
    }
}
