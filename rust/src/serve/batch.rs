//! [`BatchScheduler`] — traffic shaping in front of [`PlanService`].
//!
//! The plan cache and single-flight layer (PR 1) make *identical*
//! concurrent requests cheap, but under heavy traffic the serve layer
//! still drained its queue one request at a time with no backpressure —
//! the paper's off-chip-bottleneck shape, moved up into the deployment
//! service. This module adds the missing traffic controls:
//!
//! * **Admission control** — bounded queues with configurable capacity
//!   and a full-queue policy: [`AdmissionPolicy::Shed`] rejects
//!   immediately (the request resolves to [`BatchOutcome::Shed`], the
//!   protocol's `SHED`), [`AdmissionPolicy::Block`] applies backpressure
//!   by parking the submitter until space frees up. Requests may carry a
//!   deadline; one that expires before dispatch resolves to
//!   [`BatchOutcome::TimedOut`] (`TIMEOUT`) instead of doing dead work.
//! * **Priority lanes + weighted fair queuing** — the queue is a set of
//!   named [`lanes`](super::lanes) (`DEPLOY ... lane=<name>`; unknown or
//!   absent names fall to the `default` lane), each with its own
//!   bounded FIFO, weight, and optional per-lane admission policy. The
//!   dispatcher serves one batch per quantum from the lane picked by
//!   virtual-time weighted fair queuing, then charges the lane the
//!   *cold work* the batch actually cost (one unit per
//!   branch-and-bound solve and one per simulator run — cache hits are
//!   free). Under saturation the cold work therefore splits across
//!   lanes in proportion to their weights (a 3:1 weight ratio yields a
//!   3:1 cold-work split, within one batch window), one aggressive
//!   tenant can no longer starve the rest, and a single default lane
//!   reproduces the old single-FIFO scheduler exactly.
//! * **SoC-grouped batching** — within a quantum's batch, the
//!   dispatcher sorts by SoC fingerprint (then full plan fingerprint)
//!   and walks runs: requests targeting the same SoC solve back-to-back
//!   so the solver and cost models stay warm, and each run of
//!   *identical* fingerprints is solved and simulated **once**, with
//!   the result fanned out to every waiter in the run.
//!
//! Every request is also **traced** (see [`super::trace`]): the
//! scheduler allocates a monotonic trace id at admission, stamps stage
//! offsets (queued → picked → solved → simmed) as the request moves
//! through the pipeline, and records served latency into per-lane ×
//! warm/cold histograms plus a scheduler-wide one. `STATS` carries the
//! resulting `latency` and `server` blocks, `METRICS` renders every
//! counter and histogram as Prometheus-style text, and `TRACE [n]` /
//! `SLOW [n]` dump recent / over-threshold spans as JSON lines.
//! Disabling tracing (`--trace-cap 0`) removes the tracer entirely, so
//! the warm fast path pays nothing for it.
//!
//! Batching composes with (rather than replaces) the caches underneath:
//! a fully warm request short-circuits into the caches without ever
//! entering any lane (the fast path is lane-agnostic — batching and
//! fairness only exist to arbitrate *cold* work), fan-out handles
//! identical requests within a batch, the plan + sim caches handle
//! repeats across batches, and single-flight handles races between
//! parallel dispatch runs, fast-path callers and sync callers. Within a
//! batch, each distinct SoC gets its own dispatch run: same-SoC groups
//! solve back-to-back for locality, distinct SoCs solve in parallel.
//!
//! Scheduling is deterministic by construction: lane selection is a
//! pure function of the per-lane virtual finish tags (integer fixed
//! point, ties to the lowest lane index) and the charged costs are
//! cache-outcome counts (thread-count independent), so the fairness
//! property tests drive the same [`LaneSet`] the dispatcher uses under
//! a virtual clock and assert exact shares.

#![forbid(unsafe_code)]

use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::{anyhow, bail, Result};

use crate::config::DeployConfig;
use crate::ir::Graph;
use crate::metrics::{expo, BatchStats, LaneStats};
use crate::util::json::Json;

use super::fingerprint::{fingerprint, soc_fingerprint, Fingerprint};
use super::lanes::{normalize_specs, LaneCounters, LaneSet, LaneSpec};
use super::proto::{self, EventSink};
use super::service::{resolve_workload, PlanService, ServeReply};
use super::trace::{ActiveSpan, TraceOptions, Tracer};
use super::wfq::SCALE;

/// What admission control does with a new request when the queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Reject immediately — the request resolves to [`BatchOutcome::Shed`].
    Shed,
    /// Apply backpressure — park the submitting thread until space frees.
    #[default]
    Block,
}

/// Tunables for a [`BatchScheduler`].
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// Bounded-queue capacity of the implicit `default` lane (and of
    /// any lane spec that does not override it — see `lanes`). **Zero
    /// admits nothing**: every request is shed regardless of policy
    /// (blocking on a queue that can never drain would deadlock the
    /// submitter).
    pub queue_capacity: usize,
    /// How long the dispatcher holds a batch open after the first
    /// request arrives, letting the queues fill so grouping has
    /// something to group. Zero dispatches whatever is queued
    /// immediately.
    pub batch_window: Duration,
    /// Max requests per dispatched batch (clamped to `>= 1`).
    pub max_batch: usize,
    /// Scheduler-wide full-queue policy (lanes may override per lane).
    pub policy: AdmissionPolicy,
    /// Priority lanes. Empty means a single `default` lane of weight 1
    /// and capacity `queue_capacity` — the pre-lane FIFO scheduler,
    /// bit-for-bit. A non-empty set without a `default` lane gets one
    /// prepended (unknown `lane=` names must always land somewhere).
    pub lanes: Vec<LaneSpec>,
    /// Request tracing (`--trace-cap`, `--slowlog-ms`). Enabled by
    /// default; `enabled: false` builds the scheduler without a tracer
    /// at all.
    pub trace: TraceOptions,
}

impl Default for BatchOptions {
    fn default() -> Self {
        Self {
            queue_capacity: 256,
            batch_window: Duration::from_millis(2),
            max_batch: 64,
            policy: AdmissionPolicy::Block,
            lanes: Vec::new(),
            trace: TraceOptions::default(),
        }
    }
}

/// Terminal outcome of one batched request.
pub enum BatchOutcome {
    /// Deployed — possibly via batch fan-out or the caches.
    Served(Box<ServeReply>),
    /// Rejected by admission control (full queue, shed policy).
    Shed,
    /// Deadline expired before the request was dispatched.
    TimedOut,
}

impl BatchOutcome {
    /// The reply, if the request was served.
    pub fn served(self) -> Option<ServeReply> {
        match self {
            BatchOutcome::Served(reply) => Some(*reply),
            _ => None,
        }
    }

    /// Protocol rendering of the outcome kind (`OK` / `SHED` / `TIMEOUT`).
    pub fn kind(&self) -> &'static str {
        match self {
            BatchOutcome::Served(_) => "OK",
            BatchOutcome::Shed => "SHED",
            BatchOutcome::TimedOut => "TIMEOUT",
        }
    }
}

/// One deployment request, builder-style — the consolidated entry
/// point behind the scheduler's whole deploy surface
/// ([`BatchScheduler::submit`] blocking,
/// [`BatchScheduler::submit_async`] completion-callback). Lane,
/// deadline and streaming sink are optional fields:
///
/// ```ignore
/// let req = DeployRequest::new("w", graph, config)
///     .lane("gold")
///     .deadline(Duration::from_millis(250))
///     .sink(sink);
/// let (outcome, trace_id) = scheduler.submit(req)?;
/// ```
pub struct DeployRequest {
    workload: String,
    graph: Graph,
    config: DeployConfig,
    lane: Option<String>,
    deadline: Option<Duration>,
    sink: Option<Arc<dyn EventSink>>,
}

impl DeployRequest {
    /// A request in the default lane, no deadline, no streaming.
    pub fn new(workload: impl Into<String>, graph: Graph, config: DeployConfig) -> Self {
        Self { workload: workload.into(), graph, config, lane: None, deadline: None, sink: None }
    }

    /// Route to a named priority lane (unknown names fall back to the
    /// default lane, never an error).
    pub fn lane(mut self, lane: impl Into<String>) -> Self {
        self.lane = Some(lane.into());
        self
    }

    /// Bound the pre-dispatch wait. When absent, the resolved lane's
    /// configured default deadline (if any) applies.
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Stream partial replies (`plan`, per-phase `sim` events) to this
    /// sink while the request is being served. Only the request that
    /// actually performs the work streams; fan-out waiters and warm
    /// fast-path hits collapse to their terminal frame.
    pub fn sink(mut self, sink: Arc<dyn EventSink>) -> Self {
        self.sink = Some(sink);
        self
    }
}

/// Completion callback for one scheduled deployment: invoked exactly
/// once with the terminal outcome (every path, including shed, timeout
/// and shutdown) plus the request's trace id. Runs on whichever thread
/// resolves the request — the submitter for fast-path/admission
/// outcomes, a dispatcher thread otherwise — so implementations must
/// be quick and must not block on the scheduler.
pub type DeployCompletion = Box<dyn FnOnce(Result<BatchOutcome>, Option<u64>) + Send + 'static>;

/// One admitted request waiting in its lane.
struct Pending {
    workload: String,
    graph: Graph,
    config: DeployConfig,
    /// Full plan fingerprint — the fan-out key.
    key: Fingerprint,
    /// SoC-structure fingerprint — the batch grouping key.
    soc_key: Fingerprint,
    /// Absolute dispatch deadline, if the request carries one.
    deadline: Option<Instant>,
    /// Terminal-outcome callback (span finish + caller completion),
    /// invoked exactly once by whichever thread resolves the request.
    reply: Box<dyn FnOnce(Result<BatchOutcome>) + Send>,
    /// The request's live trace span, when tracing is enabled. The
    /// queue and dispatcher mark stage offsets through it; the
    /// completion wrapper finalizes it when the outcome lands.
    span: Option<Arc<ActiveSpan>>,
    /// Streaming partial-reply sink; rides to the dispatch leader so
    /// `plan`/`sim` events flow while the work happens. Fan-out waiters
    /// collapse to their terminal frame.
    sink: Option<Arc<dyn EventSink>>,
}

/// How admission control resolved an enqueue attempt. Non-admitted
/// requests hand the `Pending` back so the caller can invoke its
/// completion.
enum Admit {
    Admitted,
    Shed(Pending),
    /// The request's deadline expired while its submitter was parked
    /// waiting for queue space (Block policy only).
    Expired(Pending),
    Closed(Pending),
}

struct QueueState {
    lanes: LaneSet<Pending>,
    open: bool,
}

struct Queue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

/// State shared between the facade and the dispatcher thread.
struct BatchInner {
    service: Arc<PlanService>,
    opts: BatchOptions,
    /// Normalized lane configuration (the `default` lane always
    /// present), index-aligned with `counters` and the queue's
    /// [`LaneSet`]. Immutable after construction, so lane names resolve
    /// without the queue lock.
    specs: Vec<LaneSpec>,
    default_lane: usize,
    /// Per-lane counters; the scheduler-wide `batch.*` stats are sums
    /// over these (see [`LaneCounters`]).
    counters: Vec<LaneCounters>,
    /// Request tracer; `None` when tracing is disabled, so a disabled
    /// scheduler carries no per-request bookkeeping at all.
    tracer: Option<Arc<Tracer>>,
    /// Construction instant — the `server.uptime_ms` origin.
    started: Instant,
    /// Construction wall-clock time (ms since the Unix epoch; 0 if the
    /// system clock is before the epoch).
    started_unix_ms: u64,
    queue: Queue,
}

impl BatchInner {
    /// Resolve a request's lane name (absent/unknown → default lane) —
    /// lock-free: the spec list is immutable after construction.
    fn resolve_lane(&self, name: Option<&str>) -> usize {
        super::lanes::resolve_lane(&self.specs, self.default_lane, name)
    }

    /// Admission control: bounded per-lane enqueue honouring the lane's
    /// full-queue policy. A blocked submitter's deadline keeps ticking:
    /// the park is bounded by it, so a deadlined request can never be
    /// stalled unboundedly by backpressure.
    ///
    /// `may_block` gates the Block policy's park: the async front door
    /// submits from its event loop and must never park, so a full
    /// Block-policy lane *sheds* async submissions instead — read
    /// backpressure (the per-connection in-flight cap) is the async
    /// path's only blocking mechanism.
    fn enqueue(&self, lane: usize, mut pending: Pending, may_block: bool) -> Admit {
        let deadline = pending.deadline;
        let capacity = self.specs[lane].capacity;
        let policy = self.specs[lane].policy.unwrap_or(self.opts.policy);
        let mut st = self.queue.state.lock().expect("batch queue poisoned");
        loop {
            if !st.open {
                return Admit::Closed(pending);
            }
            if capacity == 0 {
                // A lane that can never drain must not block (see
                // `BatchOptions::queue_capacity`).
                self.counters[lane].shed.inc();
                return Admit::Shed(pending);
            }
            // (Re-)stamp the queued offset right before the push: a
            // submitter parked by backpressure re-enters the queue now,
            // not when it first tried.
            if let Some(s) = &pending.span {
                s.mark_queued();
            }
            // The LaneSet enforces capacity; a bounced push hands the
            // request back for the policy arm below.
            pending = match st.lanes.try_push(lane, pending) {
                Ok(()) => {
                    self.queue.not_empty.notify_one();
                    return Admit::Admitted;
                }
                Err(p) => p,
            };
            if policy == AdmissionPolicy::Shed || !may_block {
                self.counters[lane].shed.inc();
                return Admit::Shed(pending);
            }
            match deadline {
                None => {
                    st = self.queue.not_full.wait(st).expect("batch queue poisoned");
                }
                Some(d) => {
                    let now = Instant::now();
                    if d <= now {
                        self.counters[lane].timeouts.inc();
                        return Admit::Expired(pending);
                    }
                    let (guard, _) = self
                        .queue
                        .not_full
                        .wait_timeout(st, d - now)
                        .expect("batch queue poisoned");
                    st = guard;
                }
            }
        }
    }

    /// Dispatcher side: wait for the first request, hold the batch
    /// window open, then let WFQ pick the lane with the smallest
    /// virtual finish tag and drain up to `max_batch` requests from it
    /// (one quantum). Returns `None` only when the scheduler is shut
    /// down and fully drained.
    fn collect(&self) -> Option<(usize, Vec<Pending>)> {
        let mut st = self.queue.state.lock().expect("batch queue poisoned");
        while st.lanes.is_all_empty() {
            if !st.open {
                return None;
            }
            st = self.queue.not_empty.wait(st).expect("batch queue poisoned");
        }
        let window = self.opts.batch_window;
        let max_batch = self.opts.max_batch.max(1);
        let t0 = Instant::now();
        while st.open && st.lanes.max_len() < max_batch {
            let elapsed = t0.elapsed();
            if elapsed >= window {
                break;
            }
            let (guard, _) = self
                .queue
                .not_empty
                .wait_timeout(st, window - elapsed)
                .expect("batch queue poisoned");
            st = guard;
        }
        let lane = st.lanes.pick().expect("a non-empty lane exists: only the dispatcher drains");
        let batch = st.lanes.drain(lane, max_batch);
        drop(st);
        self.queue.not_full.notify_all();
        Some((lane, batch))
    }

    /// Dispatch one lane's batch: group, deduplicate, solve-or-hit once
    /// per distinct fingerprint, fan out — then charge the lane the
    /// cold work the batch cost (the WFQ accounting step).
    fn dispatch(&self, lane: usize, mut batch: Vec<Pending>) {
        let counters = &self.counters[lane];
        counters.batches.inc();
        counters.batched_requests.add(batch.len() as u64);
        counters.max_batch_size.fetch_max(batch.len() as u64);
        for p in &batch {
            if let Some(s) = &p.span {
                s.mark_picked();
            }
        }
        // SoC-major order keeps the solver's working set warm across
        // consecutive groups; full-fingerprint order inside a SoC makes
        // identical requests adjacent for the run-length walk below.
        batch.sort_by_key(|p| (p.soc_key, p.key));
        let mut groups: Vec<Vec<Pending>> = Vec::new();
        for p in batch {
            let start_new = groups.last().map_or(true, |g| g[0].key != p.key);
            if start_new {
                groups.push(Vec::new());
            }
            groups.last_mut().expect("group pushed above").push(p);
        }
        // One run per distinct SoC: runs execute in parallel so
        // distinct-SoC solves don't serialize behind each other, and
        // *within* a run the distinct-fingerprint groups fan out over
        // the shared solver pool ([`crate::tiling::SolverPool`]) — one
        // batch's distinct cold requests solve concurrently, bounded by
        // the pool's global worker budget (which the per-group
        // branch-and-bound also draws from, so nesting degrades to fewer
        // workers per solve instead of oversubscribing).
        let mut soc_runs: Vec<Vec<Vec<Pending>>> = Vec::new();
        let mut last_soc: Option<Fingerprint> = None;
        for group in groups {
            let soc = group[0].soc_key;
            if last_soc != Some(soc) {
                soc_runs.push(Vec::new());
                last_soc = Some(soc);
            }
            soc_runs.last_mut().expect("run pushed above").push(group);
        }
        let pool = crate::tiling::SolverPool::global();
        if soc_runs.len() == 1 {
            pool.map(soc_runs.remove(0), |group| self.dispatch_group(lane, group));
            return;
        }
        std::thread::scope(|s| {
            for run in soc_runs {
                s.spawn(move || {
                    pool.map(run, |group| self.dispatch_group(lane, group));
                });
            }
        });
    }

    /// Account a group's cold work to its lane: bump the counter and
    /// advance the lane's WFQ virtual finish tag. Called *before* the
    /// group's replies are sent, so a caller that has observed its
    /// reply also observes the charge — and before the dispatcher picks
    /// the next quantum, so lane selection is a deterministic function
    /// of the served cold work.
    fn charge(&self, lane: usize, cost: u64) {
        if cost == 0 {
            return;
        }
        self.counters[lane].cold_work.add(cost);
        let mut st = self.queue.state.lock().expect("batch queue poisoned");
        st.lanes.charge(lane, cost);
    }

    /// One solve + one simulation for a run of identical fingerprints;
    /// every waiter gets a reply carrying its own workload label. The
    /// lane is charged the cold work performed: one unit per
    /// branch-and-bound solve, one per simulator run (zero for a fully
    /// warm group).
    fn dispatch_group(&self, lane: usize, group: Vec<Pending>) {
        let now = Instant::now();
        let (live, expired): (Vec<Pending>, Vec<Pending>) =
            group.into_iter().partition(|p| p.deadline.map_or(true, |d| d > now));
        for p in expired {
            self.counters[lane].timeouts.inc();
            (p.reply)(Ok(BatchOutcome::TimedOut));
        }
        let mut live = live.into_iter();
        let Some(leader) = live.next() else { return };
        // Panic isolation: a panicking solve must kill neither the
        // dispatcher nor the waiters parked on their completions.
        // The leader's span and event sink ride into the service so the
        // solve/sim stage offsets are stamped — and the streamed
        // `plan`/`sim` partial replies emitted — where the work actually
        // happens. Only the leader streams: fan-out waiters collapse to
        // their terminal frame (they never ran the engine).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.service.deploy_observed(
                &leader.workload,
                &leader.graph,
                &leader.config,
                leader.span.as_deref(),
                leader.sink.as_deref(),
            )
        }))
        .unwrap_or_else(|_| {
            Err(anyhow!("batch dispatcher panicked while deploying '{}'", leader.workload))
        });
        match result {
            Ok(reply) => {
                let cost = u64::from(!reply.cached) + u64::from(!reply.sim_cached);
                self.counters[lane].served.add(1 + live.len() as u64);
                self.charge(lane, cost);
                // The freshly solved (or refreshed) entries now belong to
                // this lane's warm-up priority class.
                self.service.note_lane_hit(reply.fingerprint, self.specs[lane].weight);
                for p in live {
                    // Fan-out waiters got their plan and simulation the
                    // instant the leader did.
                    if let Some(s) = &p.span {
                        s.mark_solved();
                        s.mark_simmed();
                    }
                    // Fan-out: share the plan and the simulation, rebuild
                    // only the cheap per-request report wrapper.
                    let report = reply.plan.report_with_sim(&p.workload, &p.config, reply.report.sim.clone());
                    let fanned = ServeReply {
                        plan: reply.plan.clone(),
                        report,
                        fingerprint: reply.fingerprint,
                        cached: true,
                        sim_cached: true,
                    };
                    (p.reply)(Ok(BatchOutcome::Served(Box::new(fanned))));
                }
                (leader.reply)(Ok(BatchOutcome::Served(Box::new(reply))));
            }
            Err(e) => {
                // The solver was consulted even though it failed; charge
                // one unit so a lane of poison requests can't spin the
                // dispatcher for free.
                self.charge(lane, 1);
                // anyhow::Error is not Clone; re-render the chain per waiter.
                let msg = format!("{e:#}");
                for p in live.chain(std::iter::once(leader)) {
                    (p.reply)(Err(anyhow!("batched deploy failed: {msg}")));
                }
            }
        }
    }
}

/// The batching scheduler (see module docs). Request lifecycle:
/// **admit** (per-lane bounded queue) → **schedule** (window + WFQ lane
/// pick) → **batch** (SoC grouping) → **solve-or-hit** (plan cache) →
/// **simulate-or-hit** (sim cache) → **reply** (fan-out to every waiter
/// of the fingerprint) → **charge** (cold work advances the lane's
/// virtual finish tag).
pub struct BatchScheduler {
    inner: Arc<BatchInner>,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
}

impl BatchScheduler {
    /// Start a scheduler in front of `service` (spawns the dispatcher).
    /// Panics on an invalid lane configuration (duplicate names, zero
    /// weights) — validate user input with
    /// [`normalize_specs`](super::lanes::normalize_specs) first.
    pub fn new(service: Arc<PlanService>, mut opts: BatchOptions) -> Self {
        let specs = normalize_specs(std::mem::take(&mut opts.lanes), opts.queue_capacity)
            .expect("invalid lane configuration");
        // Keep the retained options consistent with the normalized list
        // (a reader of `opts.lanes` must never see the raw input).
        opts.lanes = specs.clone();
        let default_lane = specs.iter().position(|s| s.name == super::lanes::DEFAULT_LANE).expect("default");
        let counters = specs.iter().map(|_| LaneCounters::default()).collect();
        let tracer = opts
            .trace
            .enabled
            .then(|| Arc::new(Tracer::new(opts.trace.clone(), specs.iter().map(|s| s.name.clone()).collect())));
        let inner = Arc::new(BatchInner {
            service,
            opts,
            specs: specs.clone(),
            default_lane,
            counters,
            tracer,
            started: Instant::now(),
            started_unix_ms: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_millis() as u64)
                .unwrap_or(0),
            queue: Queue {
                state: Mutex::new(QueueState { lanes: LaneSet::new(specs), open: true }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
            },
        });
        let worker = inner.clone();
        let handle = std::thread::Builder::new()
            .name("ftl-batch-dispatch".into())
            .spawn(move || {
                while let Some((lane, batch)) = worker.collect() {
                    worker.dispatch(lane, batch);
                }
            })
            .expect("spawn batch dispatcher");
        Self { inner, dispatcher: Mutex::new(Some(handle)) }
    }

    /// Scheduler with default tunables over a default service.
    pub fn with_defaults() -> Self {
        Self::new(Arc::new(PlanService::with_defaults()), BatchOptions::default())
    }

    /// The service behind the scheduler (for direct/sync callers and
    /// counter assertions).
    pub fn service(&self) -> &Arc<PlanService> {
        &self.inner.service
    }

    /// The normalized lane configuration (default lane always present).
    pub fn lane_specs(&self) -> &[LaneSpec] {
        &self.inner.specs
    }

    /// The lane name a request's `lane=` field resolves to
    /// (absent/unknown → `default`).
    pub fn lane_name(&self, lane: Option<&str>) -> &str {
        &self.inner.specs[self.inner.resolve_lane(lane)].name
    }

    /// Blocking batched deployment without a deadline, in the default lane.
    pub fn deploy(&self, workload: &str, graph: Graph, config: DeployConfig) -> Result<BatchOutcome> {
        self.deploy_in_lane(workload, graph, config, None, None)
    }

    /// Blocking batched deployment in the default lane. `deadline`
    /// bounds how long the request may wait *before dispatch*.
    pub fn deploy_with_deadline(
        &self,
        workload: &str,
        graph: Graph,
        config: DeployConfig,
        deadline: Option<Duration>,
    ) -> Result<BatchOutcome> {
        self.deploy_in_lane(workload, graph, config, None, deadline)
    }

    /// Blocking batched deployment. `lane` names the priority lane
    /// (absent/unknown → default). `deadline` bounds how long the
    /// request may wait *before dispatch* — including time parked on a
    /// full lane under [`AdmissionPolicy::Block`] and time queued in a
    /// low-weight lane behind heavier traffic; a request whose deadline
    /// passes first resolves to [`BatchOutcome::TimedOut`] without
    /// consuming solver time. A deadline of zero is already expired at
    /// enqueue.
    pub fn deploy_in_lane(
        &self,
        workload: &str,
        graph: Graph,
        config: DeployConfig,
        lane: Option<&str>,
        deadline: Option<Duration>,
    ) -> Result<BatchOutcome> {
        self.deploy_traced(workload, graph, config, lane, deadline).map(|(outcome, _)| outcome)
    }

    /// [`deploy_in_lane`](BatchScheduler::deploy_in_lane) plus the
    /// request's trace id (`None` when tracing is disabled) — what the
    /// protocol reports back as `"trace"`, so a client can correlate
    /// its reply with `TRACE`/`SLOW` output.
    ///
    /// `deploy`, `deploy_with_deadline`, `deploy_in_lane` and this are
    /// thin wrappers over [`submit`](BatchScheduler::submit) — the
    /// [`DeployRequest`] builder is the single entry point underneath.
    pub fn deploy_traced(
        &self,
        workload: &str,
        graph: Graph,
        config: DeployConfig,
        lane: Option<&str>,
        deadline: Option<Duration>,
    ) -> Result<(BatchOutcome, Option<u64>)> {
        let mut req = DeployRequest::new(workload, graph, config);
        if let Some(lane) = lane {
            req = req.lane(lane);
        }
        if let Some(deadline) = deadline {
            req = req.deadline(deadline);
        }
        self.submit(req)
    }

    /// Blocking deployment of a built [`DeployRequest`] — the
    /// consolidated entry point behind every `deploy*` wrapper. Parks
    /// the calling thread until the terminal outcome (honouring
    /// [`AdmissionPolicy::Block`] backpressure) and returns it with the
    /// request's trace id.
    pub fn submit(&self, req: DeployRequest) -> Result<(BatchOutcome, Option<u64>)> {
        let (tx, rx) = mpsc::channel();
        let done: DeployCompletion = Box::new(move |result, _trace_id| {
            tx.send(result).ok();
        });
        let trace_id = self.do_submit(req, done, true);
        match rx.recv() {
            Ok(Ok(outcome)) => Ok((outcome, trace_id)),
            Ok(Err(e)) => Err(e),
            Err(_) => bail!("batch scheduler dropped the request before replying"),
        }
    }

    /// Nonblocking deployment: the completion fires exactly once with
    /// the terminal outcome, on whichever thread resolves the request
    /// (the calling thread for warm hits and admission rejections, a
    /// dispatcher thread otherwise). Returns the trace id immediately.
    ///
    /// The async path **never parks the caller**: a full lane sheds the
    /// request even under [`AdmissionPolicy::Block`] — the front door's
    /// per-connection in-flight cap is the async backpressure mechanism
    /// (see [`super::frontend`]).
    pub fn submit_async(&self, req: DeployRequest, done: DeployCompletion) -> Option<u64> {
        self.do_submit(req, done, false)
    }

    /// The single submission path. Every request produces exactly one
    /// completion call and (when tracing is enabled) exactly one
    /// finished [`Span`](super::trace::Span): warm fast-path hits carry
    /// no queue stages, shed/timed-out requests no solve stages, and
    /// failures finish as `ERROR` before the error propagates.
    fn do_submit(&self, req: DeployRequest, done: DeployCompletion, may_block: bool) -> Option<u64> {
        let DeployRequest { workload, graph, config, lane, deadline, sink } = req;
        let lane = self.inner.resolve_lane(lane.as_deref());
        // The effective deadline: an explicit one wins, else the lane's
        // configured default bounds the request without client
        // cooperation.
        let deadline = deadline.or(self.inner.specs[lane].default_deadline);
        let active = self.inner.tracer.as_ref().map(|t| t.begin());
        let trace_id = active.as_ref().map(|a| a.id());
        // Wrap the caller's completion with the span finish so every
        // resolution path — fast path, admission, dispatcher — records
        // its outcome through one place.
        let inner = self.inner.clone();
        let span = active.clone();
        let traced_workload = workload.clone();
        let complete = move |result: Result<BatchOutcome>| {
            if let (Some(t), Some(a)) = (&inner.tracer, &span) {
                let (outcome, warm, fp) = match &result {
                    Ok(BatchOutcome::Served(reply)) => {
                        ("OK", reply.cached && reply.sim_cached, Some(reply.fingerprint))
                    }
                    Ok(BatchOutcome::Shed) => ("SHED", false, None),
                    Ok(BatchOutcome::TimedOut) => ("TIMEOUT", false, None),
                    Err(_) => ("ERROR", false, None),
                };
                t.finish(a, &traced_workload, lane, outcome, warm, fp);
            }
            done(result, trace_id);
        };
        if let Some(d) = deadline {
            if d.is_zero() {
                self.inner.counters[lane].timeouts.inc();
                complete(Ok(BatchOutcome::TimedOut));
                return trace_id;
            }
        }
        // Warm fast path: a fully cached request skips the lanes and the
        // batch window entirely — batching only exists to amortize cold
        // work (so fairness is over cold work, and warm traffic is
        // lane-agnostic by design), and the caches + single-flight below
        // stay coherent with the dispatcher regardless of which path a
        // request takes. Warm hits collapse to the terminal frame: no
        // partial events are streamed.
        if let Some(result) = self.inner.service.deploy_if_warm(&workload, &graph, &config) {
            // Tag the hit entries with this lane's weight so warm-start
            // after a restart loads the heaviest lanes first.
            if let Ok(reply) = &result {
                self.inner.service.note_lane_hit(reply.fingerprint, self.inner.specs[lane].weight);
            }
            complete(result.map(|reply| BatchOutcome::Served(Box::new(reply))));
            return trace_id;
        }
        let key = fingerprint(&graph, &config);
        let soc_key = soc_fingerprint(&config.soc);
        let pending = Pending {
            workload,
            graph,
            config,
            key,
            soc_key,
            deadline: deadline.map(|d| Instant::now() + d),
            reply: Box::new(complete),
            span: active,
            sink,
        };
        match self.inner.enqueue(lane, pending, may_block) {
            Admit::Admitted => {}
            Admit::Shed(p) => (p.reply)(Ok(BatchOutcome::Shed)),
            Admit::Expired(p) => (p.reply)(Ok(BatchOutcome::TimedOut)),
            Admit::Closed(p) => (p.reply)(Err(anyhow!("batch scheduler is shut down"))),
        }
        trace_id
    }

    /// Counter snapshot. The scheduler-wide totals are sums over the
    /// per-lane counters (`sum(lanes.*) == batch.*` by construction).
    pub fn stats(&self) -> BatchStats {
        let (depths, vtags) = {
            let st = self.inner.queue.state.lock().expect("batch queue poisoned");
            let depths: Vec<usize> = (0..st.lanes.num_lanes()).map(|i| st.lanes.len_of(i)).collect();
            let vtags: Vec<u128> = (0..st.lanes.num_lanes()).map(|i| st.lanes.vfinish(i)).collect();
            (depths, vtags)
        };
        let lanes: Vec<LaneStats> = self
            .inner
            .specs
            .iter()
            .zip(&self.inner.counters)
            .enumerate()
            .map(|(i, (spec, c))| LaneStats {
                name: spec.name.clone(),
                weight: spec.weight,
                capacity: spec.capacity,
                queue_depth: depths[i],
                batches: c.batches.get(),
                batched_requests: c.batched_requests.get(),
                max_batch_size: c.max_batch_size.get(),
                shed: c.shed.get(),
                timeouts: c.timeouts.get(),
                served: c.served.get(),
                cold_work: c.cold_work.get(),
                // Virtual finish tag in milli-cost-units (fixed point
                // rescaled); monotone per lane.
                vtime_milli: (vtags[i].saturating_mul(1000) / SCALE) as u64,
            })
            .collect();
        BatchStats {
            batches: lanes.iter().map(|l| l.batches).sum(),
            batched_requests: lanes.iter().map(|l| l.batched_requests).sum(),
            max_batch_size: lanes.iter().map(|l| l.max_batch_size).max().unwrap_or(0),
            shed: lanes.iter().map(|l| l.shed).sum(),
            timeouts: lanes.iter().map(|l| l.timeouts).sum(),
            queue_depth: lanes.iter().map(|l| l.queue_depth).sum(),
            queue_capacity: lanes.iter().map(|l| l.capacity).sum(),
            lanes,
        }
    }

    /// Combined service + batch + server + latency stats (the
    /// protocol's `STATS` response). The `latency` block is present
    /// only when tracing is enabled.
    pub fn stats_json(&self) -> Json {
        let mut j = self.inner.service.stats_json();
        if let Json::Obj(m) = &mut j {
            m.insert("batch".into(), self.stats().to_json());
            m.insert("server".into(), self.server_json());
            if let Some(t) = &self.inner.tracer {
                m.insert("latency".into(), t.latency_json());
            }
        }
        j
    }

    /// Server identity + effective configuration (the `STATS`
    /// response's `server` block): crate version, uptime, start time,
    /// and the tunables the scheduler actually runs with — normalized
    /// lanes included, so a client sees the implicit `default` lane.
    fn server_json(&self) -> Json {
        let opts = &self.inner.opts;
        let trace = &opts.trace;
        let lanes = Json::obj(
            self.inner
                .specs
                .iter()
                .map(|s| {
                    (
                        s.name.as_str(),
                        Json::obj(vec![
                            ("weight", Json::int(s.weight)),
                            ("capacity", Json::int(s.capacity)),
                            (
                                "default_deadline_ms",
                                match s.default_deadline {
                                    Some(d) => Json::Num(d.as_millis() as f64),
                                    None => Json::Null,
                                },
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("version", Json::str(env!("CARGO_PKG_VERSION"))),
            ("uptime_ms", Json::Num(self.inner.started.elapsed().as_millis() as f64)),
            ("started_at_unix_ms", Json::Num(self.inner.started_unix_ms as f64)),
            (
                "config",
                Json::obj(vec![
                    ("queue_capacity", Json::int(opts.queue_capacity)),
                    ("batch_window_ms", Json::Num(opts.batch_window.as_millis() as f64)),
                    ("max_batch", Json::int(opts.max_batch)),
                    (
                        "policy",
                        Json::str(match opts.policy {
                            AdmissionPolicy::Shed => "shed",
                            AdmissionPolicy::Block => "block",
                        }),
                    ),
                    ("workers", Json::int(self.inner.service.stats().workers)),
                    ("solver_threads", Json::int(crate::tiling::SolverPool::global().threads())),
                    ("lanes", lanes),
                    (
                        "trace",
                        Json::obj(vec![
                            ("enabled", Json::Bool(trace.enabled)),
                            ("trace_cap", Json::int(trace.journal_cap)),
                            ("slowlog_ms", Json::Num(trace.slowlog_ms as f64)),
                            ("slowlog_cap", Json::int(trace.slowlog_cap)),
                        ]),
                    ),
                ]),
            ),
        ])
    }

    /// The request tracer — `None` when tracing is disabled
    /// (`--trace-cap 0`).
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.inner.tracer.as_ref()
    }

    /// Prometheus-style text exposition (the `METRICS` response): every
    /// scalar of [`stats_json`](BatchScheduler::stats_json) flattened
    /// under the `ftl_` prefix, plus the latency histograms emitted
    /// with `lane`/`temp` labels instead of path-mangled names.
    /// Terminated by `# EOF`.
    pub fn metrics_text(&self) -> String {
        let mut samples = expo::flatten("ftl", &self.stats_json(), &["latency"]);
        if let Some(t) = &self.inner.tracer {
            for (i, spec) in self.inner.specs.iter().enumerate() {
                let lane = spec.name.as_str();
                let warm = expo::hist_samples("ftl_latency_us", &[("lane", lane), ("temp", "warm")], t.warm_hist(i));
                let cold = expo::hist_samples("ftl_latency_us", &[("lane", lane), ("temp", "cold")], t.cold_hist(i));
                samples.extend(warm);
                samples.extend(cold);
            }
            samples.extend(expo::hist_samples("ftl_latency_total_us", &[], t.overall()));
            samples.extend(expo::hist_samples("ftl_queue_us", &[], t.queue_hist()));
        }
        expo::render(&samples)
    }

    /// Close the queues, drain what's already admitted, and stop the
    /// dispatcher (also runs on drop). New cold requests are rejected;
    /// fully warm requests may still be served via the cache fast path
    /// (the underlying [`PlanService`] is not shut down).
    pub fn shutdown(&self) {
        {
            let mut st = self.inner.queue.state.lock().expect("batch queue poisoned");
            st.open = false;
        }
        self.inner.queue.not_empty.notify_all();
        self.inner.queue.not_full.notify_all();
        if let Some(handle) = self.dispatcher.lock().expect("batch dispatcher poisoned").take() {
            handle.join().ok();
        }
    }
}

impl Drop for BatchScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Handle one single-JSON-response line of the serve protocol:
///
/// ```text
/// DEPLOY <workload> <soc> <strategy> [deadline-ms] [lane=<name>]
///     -> deploy report JSON + "outcome": "OK", "cached", "sim_cached",
///        "lane", "fingerprint", "trace" (the trace id, when tracing is
///        enabled) — or {"outcome": "SHED"|"TIMEOUT", "lane": ...,
///        "trace": ..., "error": ...} when admission control rejects or
///        the deadline expires. An unknown lane name falls back to the
///        default lane, never an error.
/// STATS -> service + batch counter snapshot (incl. lanes.<name>.*,
///          the "server" identity/config block and, when tracing is
///          enabled, the "latency" histogram block)
/// PING  -> {"pong": true}
/// ```
///
/// Errors never escape: they come back as one `{"error": ...}` object so
/// a bad request can't kill a connection handler. Connection handlers
/// should speak [`handle_command`], which adds the multi-line
/// observability commands (`METRICS`, `TRACE`, `SLOW`) on top of this.
pub fn handle_line(scheduler: &BatchScheduler, line: &str) -> Json {
    match handle_request(scheduler, line) {
        Ok(j) => j,
        Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
    }
}

/// Handle one typed [`Request`](proto::Request) to its complete
/// response text — the framing-independent core shared by
/// [`handle_command`] (v0 lines), the async front door's v1 path
/// ([`super::frontend`]) and the v1 collapse in [`handle_command`].
/// Deploys block until their terminal outcome; errors come back as one
/// `{"error": ...}` object, never a panic or a dropped response.
pub fn handle_typed(scheduler: &BatchScheduler, request: &proto::Request) -> String {
    match request {
        proto::Request::Metrics => scheduler.metrics_text().trim_end().to_string(),
        proto::Request::Trace { n } | proto::Request::Slow { n } => {
            let Some(tracer) = scheduler.tracer() else {
                return Json::obj(vec![("error", Json::str("tracing is disabled (--trace-cap 0)"))]).to_string();
            };
            let spans = match request {
                proto::Request::Trace { .. } => tracer.recent(*n),
                _ => tracer.slow(*n),
            };
            tracer.dump(&spans)
        }
        proto::Request::Stats => scheduler.stats_json().to_string(),
        proto::Request::Ping => Json::obj(vec![("pong", Json::Bool(true))]).to_string(),
        proto::Request::Deploy(cmd) => match deploy_typed(scheduler, cmd) {
            Ok(j) => j.to_string(),
            Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string(),
        },
    }
}

/// Resolve a parsed `DEPLOY` command's workload/SoC/strategy names to
/// the graph + config the scheduler consumes — shared by the blocking
/// handlers here and the async front door.
pub(crate) fn build_deploy(cmd: &proto::DeployCommand) -> Result<(Graph, DeployConfig)> {
    let strategy = crate::tiling::Strategy::parse(&cmd.strategy)
        .ok_or_else(|| anyhow!("bad strategy '{}'", cmd.strategy))?;
    let graph = resolve_workload(&cmd.workload)?;
    let cfg = DeployConfig::preset(&cmd.soc, strategy)?;
    Ok((graph, cfg))
}

/// Render a terminal [`BatchOutcome`] as the protocol's single-line
/// reply body — `outcome`/`cached`/`sim_cached`/`lane`/`fingerprint`/
/// `trace` merged into the deploy report for `OK`, or the
/// `SHED`/`TIMEOUT` error objects. Shared by the blocking line
/// handlers and the front door's terminal `done` events.
pub fn outcome_to_json(
    outcome: &BatchOutcome,
    lane_name: &str,
    trace_id: Option<u64>,
    soc: &crate::soc::SocConfig,
) -> Json {
    match outcome {
        BatchOutcome::Served(reply) => {
            let mut j = reply.report.to_json(soc);
            if let Json::Obj(m) = &mut j {
                m.insert("outcome".into(), Json::str("OK"));
                m.insert("cached".into(), Json::Bool(reply.cached));
                m.insert("sim_cached".into(), Json::Bool(reply.sim_cached));
                m.insert("lane".into(), Json::str(lane_name));
                m.insert("fingerprint".into(), Json::str(reply.fingerprint.hex()));
                if let Some(id) = trace_id {
                    m.insert("trace".into(), Json::Num(id as f64));
                }
            }
            j
        }
        BatchOutcome::Shed => {
            let mut fields = vec![
                ("outcome", Json::str("SHED")),
                ("lane", Json::str(lane_name)),
                ("error", Json::str("queue full: request shed by admission control")),
            ];
            if let Some(id) = trace_id {
                fields.push(("trace", Json::Num(id as f64)));
            }
            Json::obj(fields)
        }
        BatchOutcome::TimedOut => {
            let mut fields = vec![
                ("outcome", Json::str("TIMEOUT")),
                ("lane", Json::str(lane_name)),
                ("error", Json::str("deadline expired before the request was dispatched")),
            ];
            if let Some(id) = trace_id {
                fields.push(("trace", Json::Num(id as f64)));
            }
            Json::obj(fields)
        }
    }
}

/// Handle one protocol command — [`handle_line`] plus the multi-line
/// observability commands, the single implementation behind both
/// `ftl serve` and `examples/deploy_server.rs`:
///
/// ```text
/// METRICS   -> Prometheus-style text exposition, "# EOF"-terminated
/// TRACE [n] -> {"spans": N} header + the n newest journal spans as
///              JSON lines, newest first (default 16)
/// SLOW  [n] -> same shape, over-threshold spans from the slowlog
/// ```
///
/// Single-line commands return their JSON object rendered to text;
/// errors stay one `{"error": ...}` object (`TRACE`/`SLOW` with tracing
/// disabled included). The response never carries a trailing newline —
/// connection handlers add their own line termination.
pub fn handle_command(scheduler: &BatchScheduler, line: &str) -> String {
    match proto::Frame::parse(line) {
        Ok(frame) => match frame.version {
            proto::Version::V0 => handle_typed(scheduler, &frame.request),
            // The blocking path may collapse a v1 deploy to its single
            // terminal frame; the async front door is the streaming
            // implementation of the same vocabulary.
            proto::Version::V1 => {
                proto::wrap_v1(frame.id.unwrap_or(0), &handle_typed(scheduler, &frame.request))
            }
        },
        Err(e) => {
            let msg = format!("{e:#}");
            if line.split_whitespace().next() == Some(proto::V1_TAG) {
                // Malformed v1 frame: answer as an error event on the
                // recoverable id (0 when even the id is unreadable).
                proto::Event::Error { message: msg }.render(proto::id_hint(line).unwrap_or(0))
            } else {
                Json::obj(vec![("error", Json::str(msg))]).to_string()
            }
        }
    }
}

fn handle_request(scheduler: &BatchScheduler, line: &str) -> Result<Json> {
    let frame = proto::Frame::parse(line)?;
    match &frame.request {
        proto::Request::Deploy(cmd) => deploy_typed(scheduler, cmd),
        proto::Request::Stats => Ok(scheduler.stats_json()),
        proto::Request::Ping => Ok(Json::obj(vec![("pong", Json::Bool(true))])),
        // METRICS/TRACE/SLOW are multi-line: only `handle_command` (and
        // the front door) serve them. Same diagnostic as an unknown
        // command, so `handle_line` behavior is unchanged.
        _ => bail!(
            "bad request: '{line}' (expected: DEPLOY <workload> <soc> <strategy> [deadline-ms] [lane=<name>] \
             | STATS | METRICS | TRACE [n] | SLOW [n] | PING)"
        ),
    }
}

fn deploy_typed(scheduler: &BatchScheduler, cmd: &proto::DeployCommand) -> Result<Json> {
    let (graph, cfg) = build_deploy(cmd)?;
    let soc_cfg = cfg.soc.clone();
    let lane_name = scheduler.lane_name(cmd.lane.as_deref()).to_string();
    let mut req = DeployRequest::new(cmd.workload.clone(), graph, cfg);
    if let Some(lane) = &cmd.lane {
        req = req.lane(lane.clone());
    }
    if let Some(deadline) = cmd.deadline() {
        req = req.deadline(deadline);
    }
    let (outcome, trace_id) = scheduler.submit(req)?;
    Ok(outcome_to_json(&outcome, &lane_name, trace_id, &soc_cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments;
    use crate::serve::ServeOptions;
    use crate::tiling::Strategy;

    fn small() -> (Graph, DeployConfig) {
        (
            experiments::vit_mlp_stage(16, 24, 48),
            DeployConfig::preset("cluster-only", Strategy::Ftl).unwrap(),
        )
    }

    fn small_service() -> Arc<PlanService> {
        Arc::new(PlanService::new(ServeOptions {
            cache_capacity: 8,
            cache_shards: 2,
            workers: 1,
            ..ServeOptions::default()
        }))
    }

    #[test]
    fn zero_capacity_queue_admits_nothing() {
        for policy in [AdmissionPolicy::Shed, AdmissionPolicy::Block] {
            let sched = BatchScheduler::new(
                small_service(),
                BatchOptions { queue_capacity: 0, policy, ..BatchOptions::default() },
            );
            let (g, c) = small();
            let outcome = sched.deploy("z", g, c).unwrap();
            assert!(matches!(outcome, BatchOutcome::Shed), "zero capacity must shed ({policy:?})");
            assert_eq!(sched.stats().shed, 1);
            assert_eq!(sched.service().stats().requests, 0, "shed requests must not reach the solver");
        }
    }

    #[test]
    fn expired_deadline_times_out_at_enqueue() {
        let sched = BatchScheduler::new(small_service(), BatchOptions::default());
        let (g, c) = small();
        let outcome = sched.deploy_with_deadline("late", g, c, Some(Duration::ZERO)).unwrap();
        assert!(matches!(outcome, BatchOutcome::TimedOut));
        assert_eq!(sched.stats().timeouts, 1);
        assert_eq!(sched.service().stats().requests, 0);
    }

    #[test]
    fn served_outcome_roundtrips_through_protocol() {
        let sched = BatchScheduler::new(
            small_service(),
            BatchOptions { batch_window: Duration::ZERO, ..BatchOptions::default() },
        );
        let j = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl");
        assert!(j.get_opt("error").is_none(), "unexpected error: {j}");
        assert_eq!(j.get("outcome").unwrap().as_str().unwrap(), "OK");
        assert_eq!(j.get("lane").unwrap().as_str().unwrap(), "default");
        assert!(j.get("sim").unwrap().get("total_cycles").unwrap().as_usize().unwrap() > 0);
        // Warm repeat: both caches hit, and the fast path keeps the
        // request out of the batch queue entirely.
        let j2 = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl");
        assert!(j2.get("cached").unwrap().as_bool().unwrap());
        assert!(j2.get("sim_cached").unwrap().as_bool().unwrap());
        let stats = handle_line(&sched, "STATS");
        assert_eq!(stats.get("solves").unwrap().as_usize().unwrap(), 1);
        assert_eq!(stats.get("sims").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            stats.get("batch").unwrap().get("batched_requests").unwrap().as_usize().unwrap(),
            1,
            "the warm repeat must bypass the queue"
        );
        // Per-lane counters ride along under batch.lanes.<name>.*.
        let lane = stats.get("batch").unwrap().get("lanes").unwrap().get("default").unwrap();
        assert_eq!(lane.get("batched_requests").unwrap().as_usize().unwrap(), 1);
        assert_eq!(lane.get("weight").unwrap().as_usize().unwrap(), 1);
        assert!(lane.get("cold_work").unwrap().as_usize().unwrap() >= 1, "the cold deploy must be charged");
    }

    #[test]
    fn protocol_routes_lane_field_and_unknown_lane_falls_back() {
        let sched = BatchScheduler::new(
            small_service(),
            BatchOptions {
                batch_window: Duration::ZERO,
                lanes: vec![LaneSpec::new("gold", 3, 8)],
                ..BatchOptions::default()
            },
        );
        let j = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl lane=gold");
        assert!(j.get_opt("error").is_none(), "unexpected error: {j}");
        assert_eq!(j.get("lane").unwrap().as_str().unwrap(), "gold");
        let j2 = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only baseline lane=no-such-lane");
        assert!(j2.get_opt("error").is_none(), "unknown lane must fall back, not error: {j2}");
        assert_eq!(j2.get("lane").unwrap().as_str().unwrap(), "default");
        // Deadline and lane compose in either order.
        let j3 = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl lane=gold 5000");
        assert!(j3.get_opt("error").is_none(), "{j3}");
        let j4 = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl 5000 lane=gold");
        assert!(j4.get_opt("error").is_none(), "{j4}");
        let batch = sched.stats_json().get("batch").unwrap().clone();
        let gold = batch.get("lanes").unwrap().get("gold").unwrap().clone();
        assert_eq!(gold.get("batched_requests").unwrap().as_usize().unwrap(), 1, "one cold request in gold");
        // Duplicate fields are protocol errors.
        for bad in [
            "DEPLOY vit-tiny-stage cluster-only ftl lane=a lane=b",
            "DEPLOY vit-tiny-stage cluster-only ftl 5 6",
        ] {
            assert!(handle_line(&sched, bad).get_opt("error").is_some(), "'{bad}' must error");
        }
    }

    #[test]
    fn protocol_errors_become_json_not_panics() {
        let sched = BatchScheduler::new(small_service(), BatchOptions::default());
        for bad in [
            "",
            "DEPLOY",
            "DEPLOY x",
            "DEPLOY a b c d e",
            "NOPE x y z",
            "DEPLOY no-such-net siracusa ftl",
            "DEPLOY vit-tiny-stage no-such-soc ftl",
            "DEPLOY vit-tiny-stage siracusa no-such-strategy",
            "DEPLOY vit-tiny-stage siracusa ftl not-a-number",
        ] {
            let j = handle_line(&sched, bad);
            assert!(j.get_opt("error").is_some(), "'{bad}' must yield an error object, got {j}");
        }
        let pong = handle_line(&sched, "PING");
        assert!(pong.get("pong").unwrap().as_bool().unwrap());
        let stats = handle_line(&sched, "STATS");
        assert_eq!(stats.get("solves").unwrap().as_usize().unwrap(), 0);
        assert_eq!(stats.get("batch").unwrap().get("shed").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn metrics_trace_slow_protocol_commands() {
        let sched = BatchScheduler::new(
            small_service(),
            BatchOptions { batch_window: Duration::ZERO, ..BatchOptions::default() },
        );
        let j = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl");
        assert!(j.get_opt("error").is_none(), "{j}");
        assert!(j.get("trace").unwrap().as_u64().unwrap() >= 1, "replies must carry the trace id");
        // METRICS is EOF-terminated and round-trips through the strict
        // exposition parser, cold latency included.
        let metrics = handle_command(&sched, "METRICS");
        assert!(metrics.ends_with("# EOF"), "METRICS must end with the EOF marker");
        let samples = crate::metrics::expo::parse(&metrics).unwrap();
        assert!(
            samples.iter().any(|s| s.name == "ftl_latency_total_us_count" && s.value >= 1.0),
            "the served request must show up in the overall latency histogram"
        );
        // TRACE dumps a {"spans": N} header plus one JSON line per span.
        let trace = handle_command(&sched, "TRACE 8");
        let mut lines = trace.lines();
        let header = crate::util::json::parse(lines.next().unwrap()).unwrap();
        assert!(header.get("spans").unwrap().as_usize().unwrap() >= 1);
        let span = crate::util::json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(span.get("outcome").unwrap().as_str().unwrap(), "OK");
        // STATS grows the server identity and latency blocks.
        let stats = handle_line(&sched, "STATS");
        let server = stats.get("server").unwrap();
        assert_eq!(server.get("version").unwrap().as_str().unwrap(), env!("CARGO_PKG_VERSION"));
        assert!(server.get("config").unwrap().get("lanes").unwrap().get("default").is_ok());
        let overall = stats.get("latency").unwrap().get("overall").unwrap();
        assert!(overall.get("count").unwrap().as_u64().unwrap() >= 1);
        // SLOW parses even when empty; a disabled tracer yields an
        // error object (and no latency block), never a panic.
        let slow = handle_command(&sched, "SLOW");
        let slow_header = crate::util::json::parse(slow.lines().next().unwrap()).unwrap();
        assert!(slow_header.get("spans").is_ok());
        let off = BatchScheduler::new(
            small_service(),
            BatchOptions { trace: TraceOptions::disabled(), ..BatchOptions::default() },
        );
        let denied = handle_command(&off, "TRACE");
        assert!(crate::util::json::parse(&denied).unwrap().get("error").is_ok());
        assert!(handle_line(&off, "STATS").get_opt("latency").is_none());
        assert!(handle_command(&off, "TRACE nope").contains("error"));
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let sched = BatchScheduler::new(small_service(), BatchOptions::default());
        sched.shutdown();
        let (g, c) = small();
        assert!(sched.deploy("late", g, c).is_err());
    }

    #[test]
    fn submit_async_completes_via_callback() {
        let sched = BatchScheduler::new(small_service(), BatchOptions::default());
        let (g, c) = small();
        let (tx, rx) = mpsc::channel();
        let id = sched.submit_async(
            DeployRequest::new("async", g, c),
            Box::new(move |result, trace_id| {
                tx.send((result.map(|o| o.kind()), trace_id)).ok();
            }),
        );
        let (kind, cb_id) = rx.recv().unwrap();
        assert_eq!(kind.unwrap(), "OK");
        assert_eq!(cb_id, id, "the completion must see the same trace id submit_async returned");
        assert!(id.unwrap() >= 1);
    }

    #[test]
    fn async_submission_sheds_instead_of_parking() {
        // A zero-capacity Block-policy lane would park a blocking
        // submitter forever; the async path must shed instead.
        let sched = BatchScheduler::new(
            small_service(),
            BatchOptions { queue_capacity: 0, policy: AdmissionPolicy::Block, ..BatchOptions::default() },
        );
        let (g, c) = small();
        let (tx, rx) = mpsc::channel();
        sched.submit_async(
            DeployRequest::new("full", g, c),
            Box::new(move |result, _| {
                tx.send(result.map(|o| o.kind())).ok();
            }),
        );
        assert_eq!(rx.recv().unwrap().unwrap(), "SHED");
        assert_eq!(sched.stats().shed, 1);
    }

    #[test]
    fn lane_default_deadline_applies_when_request_has_none() {
        let mut lane = LaneSpec::new("bounded", 1, 8);
        lane.default_deadline = Some(Duration::ZERO);
        let sched = BatchScheduler::new(
            small_service(),
            BatchOptions { lanes: vec![lane], ..BatchOptions::default() },
        );
        let (g, c) = small();
        let outcome =
            sched.deploy_in_lane("defaulted", g.clone(), c.clone(), Some("bounded"), None).unwrap();
        assert!(matches!(outcome, BatchOutcome::TimedOut), "the lane's zero default deadline must expire it");
        // An explicit client deadline wins over the lane default.
        let outcome = sched
            .deploy_in_lane("explicit", g, c, Some("bounded"), Some(Duration::from_secs(60)))
            .unwrap();
        assert!(matches!(outcome, BatchOutcome::Served(_)));
        // And STATS surfaces the effective default.
        let stats = handle_line(&sched, "STATS");
        let lanes = stats.get("server").unwrap().get("config").unwrap().get("lanes").unwrap();
        let ms = lanes.get("bounded").unwrap().get("default_deadline_ms").unwrap().as_f64().unwrap();
        assert_eq!(ms, 0.0);
        assert!(matches!(
            lanes.get("default").unwrap().get("default_deadline_ms").unwrap(),
            Json::Null
        ));
    }
}
