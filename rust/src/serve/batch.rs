//! [`BatchScheduler`] — traffic shaping in front of [`PlanService`].
//!
//! The plan cache and single-flight layer (PR 1) make *identical*
//! concurrent requests cheap, but under heavy traffic the serve layer
//! still drained its queue one request at a time with no backpressure —
//! the paper's off-chip-bottleneck shape, moved up into the deployment
//! service. This module adds the missing traffic controls:
//!
//! * **Admission control** — bounded queues with configurable capacity
//!   and a full-queue policy: [`AdmissionPolicy::Shed`] rejects
//!   immediately (the request resolves to [`BatchOutcome::Shed`], the
//!   protocol's `SHED`), [`AdmissionPolicy::Block`] applies backpressure
//!   by parking the submitter until space frees up. Requests may carry a
//!   deadline; one that expires before dispatch resolves to
//!   [`BatchOutcome::TimedOut`] (`TIMEOUT`) instead of doing dead work.
//! * **Priority lanes + weighted fair queuing** — the queue is a set of
//!   named [`lanes`](super::lanes) (`DEPLOY ... lane=<name>`; unknown or
//!   absent names fall to the `default` lane), each with its own
//!   bounded FIFO, weight, and optional per-lane admission policy. The
//!   dispatcher serves one batch per quantum from the lane picked by
//!   virtual-time weighted fair queuing, then charges the lane the
//!   *cold work* the batch actually cost (one unit per
//!   branch-and-bound solve and one per simulator run — cache hits are
//!   free). Under saturation the cold work therefore splits across
//!   lanes in proportion to their weights (a 3:1 weight ratio yields a
//!   3:1 cold-work split, within one batch window), one aggressive
//!   tenant can no longer starve the rest, and a single default lane
//!   reproduces the old single-FIFO scheduler exactly.
//! * **SoC-grouped batching** — within a quantum's batch, the
//!   dispatcher sorts by SoC fingerprint (then full plan fingerprint)
//!   and walks runs: requests targeting the same SoC solve back-to-back
//!   so the solver and cost models stay warm, and each run of
//!   *identical* fingerprints is solved and simulated **once**, with
//!   the result fanned out to every waiter in the run.
//!
//! Every request is also **traced** (see [`super::trace`]): the
//! scheduler allocates a monotonic trace id at admission, stamps stage
//! offsets (queued → picked → solved → simmed) as the request moves
//! through the pipeline, and records served latency into per-lane ×
//! warm/cold histograms plus a scheduler-wide one. `STATS` carries the
//! resulting `latency` and `server` blocks, `METRICS` renders every
//! counter and histogram as Prometheus-style text, and `TRACE [n]` /
//! `SLOW [n]` dump recent / over-threshold spans as JSON lines.
//! Disabling tracing (`--trace-cap 0`) removes the tracer entirely, so
//! the warm fast path pays nothing for it.
//!
//! Batching composes with (rather than replaces) the caches underneath:
//! a fully warm request short-circuits into the caches without ever
//! entering any lane (the fast path is lane-agnostic — batching and
//! fairness only exist to arbitrate *cold* work), fan-out handles
//! identical requests within a batch, the plan + sim caches handle
//! repeats across batches, and single-flight handles races between
//! parallel dispatch runs, fast-path callers and sync callers. Within a
//! batch, each distinct SoC gets its own dispatch run: same-SoC groups
//! solve back-to-back for locality, distinct SoCs solve in parallel.
//!
//! Scheduling is deterministic by construction: lane selection is a
//! pure function of the per-lane virtual finish tags (integer fixed
//! point, ties to the lowest lane index) and the charged costs are
//! cache-outcome counts (thread-count independent), so the fairness
//! property tests drive the same [`LaneSet`] the dispatcher uses under
//! a virtual clock and assert exact shares.

use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::{anyhow, bail, Result};

use crate::config::DeployConfig;
use crate::ir::Graph;
use crate::metrics::{expo, BatchStats, LaneStats};
use crate::util::json::Json;

use super::fingerprint::{fingerprint, soc_fingerprint, Fingerprint};
use super::lanes::{normalize_specs, LaneCounters, LaneSet, LaneSpec};
use super::service::{resolve_workload, PlanService, ServeReply};
use super::trace::{ActiveSpan, TraceOptions, Tracer};
use super::wfq::SCALE;

/// What admission control does with a new request when the queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Reject immediately — the request resolves to [`BatchOutcome::Shed`].
    Shed,
    /// Apply backpressure — park the submitting thread until space frees.
    #[default]
    Block,
}

/// Tunables for a [`BatchScheduler`].
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// Bounded-queue capacity of the implicit `default` lane (and of
    /// any lane spec that does not override it — see `lanes`). **Zero
    /// admits nothing**: every request is shed regardless of policy
    /// (blocking on a queue that can never drain would deadlock the
    /// submitter).
    pub queue_capacity: usize,
    /// How long the dispatcher holds a batch open after the first
    /// request arrives, letting the queues fill so grouping has
    /// something to group. Zero dispatches whatever is queued
    /// immediately.
    pub batch_window: Duration,
    /// Max requests per dispatched batch (clamped to `>= 1`).
    pub max_batch: usize,
    /// Scheduler-wide full-queue policy (lanes may override per lane).
    pub policy: AdmissionPolicy,
    /// Priority lanes. Empty means a single `default` lane of weight 1
    /// and capacity `queue_capacity` — the pre-lane FIFO scheduler,
    /// bit-for-bit. A non-empty set without a `default` lane gets one
    /// prepended (unknown `lane=` names must always land somewhere).
    pub lanes: Vec<LaneSpec>,
    /// Request tracing (`--trace-cap`, `--slowlog-ms`). Enabled by
    /// default; `enabled: false` builds the scheduler without a tracer
    /// at all.
    pub trace: TraceOptions,
}

impl Default for BatchOptions {
    fn default() -> Self {
        Self {
            queue_capacity: 256,
            batch_window: Duration::from_millis(2),
            max_batch: 64,
            policy: AdmissionPolicy::Block,
            lanes: Vec::new(),
            trace: TraceOptions::default(),
        }
    }
}

/// Terminal outcome of one batched request.
pub enum BatchOutcome {
    /// Deployed — possibly via batch fan-out or the caches.
    Served(Box<ServeReply>),
    /// Rejected by admission control (full queue, shed policy).
    Shed,
    /// Deadline expired before the request was dispatched.
    TimedOut,
}

impl BatchOutcome {
    /// The reply, if the request was served.
    pub fn served(self) -> Option<ServeReply> {
        match self {
            BatchOutcome::Served(reply) => Some(*reply),
            _ => None,
        }
    }

    /// Protocol rendering of the outcome kind (`OK` / `SHED` / `TIMEOUT`).
    pub fn kind(&self) -> &'static str {
        match self {
            BatchOutcome::Served(_) => "OK",
            BatchOutcome::Shed => "SHED",
            BatchOutcome::TimedOut => "TIMEOUT",
        }
    }
}

/// One admitted request waiting in its lane.
struct Pending {
    workload: String,
    graph: Graph,
    config: DeployConfig,
    /// Full plan fingerprint — the fan-out key.
    key: Fingerprint,
    /// SoC-structure fingerprint — the batch grouping key.
    soc_key: Fingerprint,
    /// Absolute dispatch deadline, if the request carries one.
    deadline: Option<Instant>,
    reply: mpsc::Sender<Result<BatchOutcome>>,
    /// The request's live trace span, when tracing is enabled. The
    /// queue and dispatcher mark stage offsets through it; the
    /// submitting thread finalizes it after the reply arrives.
    span: Option<Arc<ActiveSpan>>,
}

/// How admission control resolved an enqueue attempt.
enum Admit {
    Admitted,
    Shed,
    /// The request's deadline expired while its submitter was parked
    /// waiting for queue space (Block policy only).
    Expired,
    Closed,
}

struct QueueState {
    lanes: LaneSet<Pending>,
    open: bool,
}

struct Queue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

/// State shared between the facade and the dispatcher thread.
struct BatchInner {
    service: Arc<PlanService>,
    opts: BatchOptions,
    /// Normalized lane configuration (the `default` lane always
    /// present), index-aligned with `counters` and the queue's
    /// [`LaneSet`]. Immutable after construction, so lane names resolve
    /// without the queue lock.
    specs: Vec<LaneSpec>,
    default_lane: usize,
    /// Per-lane counters; the scheduler-wide `batch.*` stats are sums
    /// over these (see [`LaneCounters`]).
    counters: Vec<LaneCounters>,
    /// Request tracer; `None` when tracing is disabled, so a disabled
    /// scheduler carries no per-request bookkeeping at all.
    tracer: Option<Arc<Tracer>>,
    /// Construction instant — the `server.uptime_ms` origin.
    started: Instant,
    /// Construction wall-clock time (ms since the Unix epoch; 0 if the
    /// system clock is before the epoch).
    started_unix_ms: u64,
    queue: Queue,
}

impl BatchInner {
    /// Resolve a request's lane name (absent/unknown → default lane) —
    /// lock-free: the spec list is immutable after construction.
    fn resolve_lane(&self, name: Option<&str>) -> usize {
        super::lanes::resolve_lane(&self.specs, self.default_lane, name)
    }

    /// Admission control: bounded per-lane enqueue honouring the lane's
    /// full-queue policy. A blocked submitter's deadline keeps ticking:
    /// the park is bounded by it, so a deadlined request can never be
    /// stalled unboundedly by backpressure.
    fn enqueue(&self, lane: usize, mut pending: Pending) -> Admit {
        let deadline = pending.deadline;
        let capacity = self.specs[lane].capacity;
        let policy = self.specs[lane].policy.unwrap_or(self.opts.policy);
        let mut st = self.queue.state.lock().expect("batch queue poisoned");
        loop {
            if !st.open {
                return Admit::Closed;
            }
            if capacity == 0 {
                // A lane that can never drain must not block (see
                // `BatchOptions::queue_capacity`).
                self.counters[lane].shed.inc();
                return Admit::Shed;
            }
            // (Re-)stamp the queued offset right before the push: a
            // submitter parked by backpressure re-enters the queue now,
            // not when it first tried.
            if let Some(s) = &pending.span {
                s.mark_queued();
            }
            // The LaneSet enforces capacity; a bounced push hands the
            // request back for the policy arm below.
            pending = match st.lanes.try_push(lane, pending) {
                Ok(()) => {
                    self.queue.not_empty.notify_one();
                    return Admit::Admitted;
                }
                Err(p) => p,
            };
            match policy {
                AdmissionPolicy::Shed => {
                    self.counters[lane].shed.inc();
                    return Admit::Shed;
                }
                AdmissionPolicy::Block => match deadline {
                    None => {
                        st = self.queue.not_full.wait(st).expect("batch queue poisoned");
                    }
                    Some(d) => {
                        let now = Instant::now();
                        if d <= now {
                            self.counters[lane].timeouts.inc();
                            return Admit::Expired;
                        }
                        let (guard, _) = self
                            .queue
                            .not_full
                            .wait_timeout(st, d - now)
                            .expect("batch queue poisoned");
                        st = guard;
                    }
                },
            }
        }
    }

    /// Dispatcher side: wait for the first request, hold the batch
    /// window open, then let WFQ pick the lane with the smallest
    /// virtual finish tag and drain up to `max_batch` requests from it
    /// (one quantum). Returns `None` only when the scheduler is shut
    /// down and fully drained.
    fn collect(&self) -> Option<(usize, Vec<Pending>)> {
        let mut st = self.queue.state.lock().expect("batch queue poisoned");
        while st.lanes.is_all_empty() {
            if !st.open {
                return None;
            }
            st = self.queue.not_empty.wait(st).expect("batch queue poisoned");
        }
        let window = self.opts.batch_window;
        let max_batch = self.opts.max_batch.max(1);
        let t0 = Instant::now();
        while st.open && st.lanes.max_len() < max_batch {
            let elapsed = t0.elapsed();
            if elapsed >= window {
                break;
            }
            let (guard, _) = self
                .queue
                .not_empty
                .wait_timeout(st, window - elapsed)
                .expect("batch queue poisoned");
            st = guard;
        }
        let lane = st.lanes.pick().expect("a non-empty lane exists: only the dispatcher drains");
        let batch = st.lanes.drain(lane, max_batch);
        drop(st);
        self.queue.not_full.notify_all();
        Some((lane, batch))
    }

    /// Dispatch one lane's batch: group, deduplicate, solve-or-hit once
    /// per distinct fingerprint, fan out — then charge the lane the
    /// cold work the batch cost (the WFQ accounting step).
    fn dispatch(&self, lane: usize, mut batch: Vec<Pending>) {
        let counters = &self.counters[lane];
        counters.batches.inc();
        counters.batched_requests.add(batch.len() as u64);
        counters.max_batch_size.fetch_max(batch.len() as u64);
        for p in &batch {
            if let Some(s) = &p.span {
                s.mark_picked();
            }
        }
        // SoC-major order keeps the solver's working set warm across
        // consecutive groups; full-fingerprint order inside a SoC makes
        // identical requests adjacent for the run-length walk below.
        batch.sort_by_key(|p| (p.soc_key, p.key));
        let mut groups: Vec<Vec<Pending>> = Vec::new();
        for p in batch {
            let start_new = groups.last().map_or(true, |g| g[0].key != p.key);
            if start_new {
                groups.push(Vec::new());
            }
            groups.last_mut().expect("group pushed above").push(p);
        }
        // One run per distinct SoC: runs execute in parallel so
        // distinct-SoC solves don't serialize behind each other, and
        // *within* a run the distinct-fingerprint groups fan out over
        // the shared solver pool ([`crate::tiling::SolverPool`]) — one
        // batch's distinct cold requests solve concurrently, bounded by
        // the pool's global worker budget (which the per-group
        // branch-and-bound also draws from, so nesting degrades to fewer
        // workers per solve instead of oversubscribing).
        let mut soc_runs: Vec<Vec<Vec<Pending>>> = Vec::new();
        let mut last_soc: Option<Fingerprint> = None;
        for group in groups {
            let soc = group[0].soc_key;
            if last_soc != Some(soc) {
                soc_runs.push(Vec::new());
                last_soc = Some(soc);
            }
            soc_runs.last_mut().expect("run pushed above").push(group);
        }
        let pool = crate::tiling::SolverPool::global();
        if soc_runs.len() == 1 {
            pool.map(soc_runs.remove(0), |group| self.dispatch_group(lane, group));
            return;
        }
        std::thread::scope(|s| {
            for run in soc_runs {
                s.spawn(move || {
                    pool.map(run, |group| self.dispatch_group(lane, group));
                });
            }
        });
    }

    /// Account a group's cold work to its lane: bump the counter and
    /// advance the lane's WFQ virtual finish tag. Called *before* the
    /// group's replies are sent, so a caller that has observed its
    /// reply also observes the charge — and before the dispatcher picks
    /// the next quantum, so lane selection is a deterministic function
    /// of the served cold work.
    fn charge(&self, lane: usize, cost: u64) {
        if cost == 0 {
            return;
        }
        self.counters[lane].cold_work.add(cost);
        let mut st = self.queue.state.lock().expect("batch queue poisoned");
        st.lanes.charge(lane, cost);
    }

    /// One solve + one simulation for a run of identical fingerprints;
    /// every waiter gets a reply carrying its own workload label. The
    /// lane is charged the cold work performed: one unit per
    /// branch-and-bound solve, one per simulator run (zero for a fully
    /// warm group).
    fn dispatch_group(&self, lane: usize, group: Vec<Pending>) {
        let now = Instant::now();
        let (live, expired): (Vec<Pending>, Vec<Pending>) =
            group.into_iter().partition(|p| p.deadline.map_or(true, |d| d > now));
        for p in expired {
            self.counters[lane].timeouts.inc();
            p.reply.send(Ok(BatchOutcome::TimedOut)).ok();
        }
        let mut live = live.into_iter();
        let Some(leader) = live.next() else { return };
        // Panic isolation: a panicking solve must kill neither the
        // dispatcher nor the waiters parked on their reply channels.
        // The leader's span rides into the service so the solve/sim
        // stage offsets are stamped where the work actually happens.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.service.deploy_spanned(&leader.workload, &leader.graph, &leader.config, leader.span.as_deref())
        }))
        .unwrap_or_else(|_| {
            Err(anyhow!("batch dispatcher panicked while deploying '{}'", leader.workload))
        });
        match result {
            Ok(reply) => {
                let cost = u64::from(!reply.cached) + u64::from(!reply.sim_cached);
                self.counters[lane].served.add(1 + live.len() as u64);
                self.charge(lane, cost);
                for p in live {
                    // Fan-out waiters got their plan and simulation the
                    // instant the leader did.
                    if let Some(s) = &p.span {
                        s.mark_solved();
                        s.mark_simmed();
                    }
                    // Fan-out: share the plan and the simulation, rebuild
                    // only the cheap per-request report wrapper.
                    let report = reply.plan.report_with_sim(&p.workload, &p.config, reply.report.sim.clone());
                    let fanned = ServeReply {
                        plan: reply.plan.clone(),
                        report,
                        fingerprint: reply.fingerprint,
                        cached: true,
                        sim_cached: true,
                    };
                    p.reply.send(Ok(BatchOutcome::Served(Box::new(fanned)))).ok();
                }
                leader.reply.send(Ok(BatchOutcome::Served(Box::new(reply)))).ok();
            }
            Err(e) => {
                // The solver was consulted even though it failed; charge
                // one unit so a lane of poison requests can't spin the
                // dispatcher for free.
                self.charge(lane, 1);
                // anyhow::Error is not Clone; re-render the chain per waiter.
                let msg = format!("{e:#}");
                for p in live.chain(std::iter::once(leader)) {
                    p.reply.send(Err(anyhow!("batched deploy failed: {msg}"))).ok();
                }
            }
        }
    }
}

/// The batching scheduler (see module docs). Request lifecycle:
/// **admit** (per-lane bounded queue) → **schedule** (window + WFQ lane
/// pick) → **batch** (SoC grouping) → **solve-or-hit** (plan cache) →
/// **simulate-or-hit** (sim cache) → **reply** (fan-out to every waiter
/// of the fingerprint) → **charge** (cold work advances the lane's
/// virtual finish tag).
pub struct BatchScheduler {
    inner: Arc<BatchInner>,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
}

impl BatchScheduler {
    /// Start a scheduler in front of `service` (spawns the dispatcher).
    /// Panics on an invalid lane configuration (duplicate names, zero
    /// weights) — validate user input with
    /// [`normalize_specs`](super::lanes::normalize_specs) first.
    pub fn new(service: Arc<PlanService>, mut opts: BatchOptions) -> Self {
        let specs = normalize_specs(std::mem::take(&mut opts.lanes), opts.queue_capacity)
            .expect("invalid lane configuration");
        // Keep the retained options consistent with the normalized list
        // (a reader of `opts.lanes` must never see the raw input).
        opts.lanes = specs.clone();
        let default_lane = specs.iter().position(|s| s.name == super::lanes::DEFAULT_LANE).expect("default");
        let counters = specs.iter().map(|_| LaneCounters::default()).collect();
        let tracer = opts
            .trace
            .enabled
            .then(|| Arc::new(Tracer::new(opts.trace.clone(), specs.iter().map(|s| s.name.clone()).collect())));
        let inner = Arc::new(BatchInner {
            service,
            opts,
            specs: specs.clone(),
            default_lane,
            counters,
            tracer,
            started: Instant::now(),
            started_unix_ms: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_millis() as u64)
                .unwrap_or(0),
            queue: Queue {
                state: Mutex::new(QueueState { lanes: LaneSet::new(specs), open: true }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
            },
        });
        let worker = inner.clone();
        let handle = std::thread::Builder::new()
            .name("ftl-batch-dispatch".into())
            .spawn(move || {
                while let Some((lane, batch)) = worker.collect() {
                    worker.dispatch(lane, batch);
                }
            })
            .expect("spawn batch dispatcher");
        Self { inner, dispatcher: Mutex::new(Some(handle)) }
    }

    /// Scheduler with default tunables over a default service.
    pub fn with_defaults() -> Self {
        Self::new(Arc::new(PlanService::with_defaults()), BatchOptions::default())
    }

    /// The service behind the scheduler (for direct/sync callers and
    /// counter assertions).
    pub fn service(&self) -> &Arc<PlanService> {
        &self.inner.service
    }

    /// The normalized lane configuration (default lane always present).
    pub fn lane_specs(&self) -> &[LaneSpec] {
        &self.inner.specs
    }

    /// The lane name a request's `lane=` field resolves to
    /// (absent/unknown → `default`).
    pub fn lane_name(&self, lane: Option<&str>) -> &str {
        &self.inner.specs[self.inner.resolve_lane(lane)].name
    }

    /// Blocking batched deployment without a deadline, in the default lane.
    pub fn deploy(&self, workload: &str, graph: Graph, config: DeployConfig) -> Result<BatchOutcome> {
        self.deploy_in_lane(workload, graph, config, None, None)
    }

    /// Blocking batched deployment in the default lane. `deadline`
    /// bounds how long the request may wait *before dispatch*.
    pub fn deploy_with_deadline(
        &self,
        workload: &str,
        graph: Graph,
        config: DeployConfig,
        deadline: Option<Duration>,
    ) -> Result<BatchOutcome> {
        self.deploy_in_lane(workload, graph, config, None, deadline)
    }

    /// Blocking batched deployment. `lane` names the priority lane
    /// (absent/unknown → default). `deadline` bounds how long the
    /// request may wait *before dispatch* — including time parked on a
    /// full lane under [`AdmissionPolicy::Block`] and time queued in a
    /// low-weight lane behind heavier traffic; a request whose deadline
    /// passes first resolves to [`BatchOutcome::TimedOut`] without
    /// consuming solver time. A deadline of zero is already expired at
    /// enqueue.
    pub fn deploy_in_lane(
        &self,
        workload: &str,
        graph: Graph,
        config: DeployConfig,
        lane: Option<&str>,
        deadline: Option<Duration>,
    ) -> Result<BatchOutcome> {
        self.deploy_traced(workload, graph, config, lane, deadline).map(|(outcome, _)| outcome)
    }

    /// [`deploy_in_lane`](BatchScheduler::deploy_in_lane) plus the
    /// request's trace id (`None` when tracing is disabled) — what the
    /// protocol reports back as `"trace"`, so a client can correlate
    /// its reply with `TRACE`/`SLOW` output. Every admitted request
    /// produces exactly one finished [`Span`](super::trace::Span): warm
    /// fast-path hits carry no queue stages, shed/timed-out requests no
    /// solve stages, and failures finish as `ERROR` before the error
    /// propagates.
    pub fn deploy_traced(
        &self,
        workload: &str,
        graph: Graph,
        config: DeployConfig,
        lane: Option<&str>,
        deadline: Option<Duration>,
    ) -> Result<(BatchOutcome, Option<u64>)> {
        let lane = self.inner.resolve_lane(lane);
        let active = self.inner.tracer.as_ref().map(|t| t.begin());
        let trace_id = active.as_ref().map(|a| a.id());
        let finish = |outcome: &'static str, warm: bool, fp: Option<Fingerprint>| {
            if let (Some(t), Some(a)) = (&self.inner.tracer, &active) {
                t.finish(a, workload, lane, outcome, warm, fp);
            }
        };
        if let Some(d) = deadline {
            if d.is_zero() {
                self.inner.counters[lane].timeouts.inc();
                finish("TIMEOUT", false, None);
                return Ok((BatchOutcome::TimedOut, trace_id));
            }
        }
        // Warm fast path: a fully cached request skips the lanes and the
        // batch window entirely — batching only exists to amortize cold
        // work (so fairness is over cold work, and warm traffic is
        // lane-agnostic by design), and the caches + single-flight below
        // stay coherent with the dispatcher regardless of which path a
        // request takes.
        if let Some(result) = self.inner.service.deploy_if_warm(workload, &graph, &config) {
            return match result {
                Ok(reply) => {
                    finish("OK", true, Some(reply.fingerprint));
                    Ok((BatchOutcome::Served(Box::new(reply)), trace_id))
                }
                Err(e) => {
                    finish("ERROR", false, None);
                    Err(e)
                }
            };
        }
        let key = fingerprint(&graph, &config);
        let soc_key = soc_fingerprint(&config.soc);
        let (tx, rx) = mpsc::channel();
        let pending = Pending {
            workload: workload.to_string(),
            graph,
            config,
            key,
            soc_key,
            deadline: deadline.map(|d| Instant::now() + d),
            reply: tx,
            span: active.clone(),
        };
        match self.inner.enqueue(lane, pending) {
            Admit::Admitted => {}
            Admit::Shed => {
                finish("SHED", false, None);
                return Ok((BatchOutcome::Shed, trace_id));
            }
            Admit::Expired => {
                finish("TIMEOUT", false, None);
                return Ok((BatchOutcome::TimedOut, trace_id));
            }
            Admit::Closed => bail!("batch scheduler is shut down"),
        }
        match rx.recv() {
            Ok(Ok(outcome)) => {
                let (warm, fp) = match &outcome {
                    BatchOutcome::Served(reply) => (reply.cached && reply.sim_cached, Some(reply.fingerprint)),
                    _ => (false, None),
                };
                finish(outcome.kind(), warm, fp);
                Ok((outcome, trace_id))
            }
            Ok(Err(e)) => {
                finish("ERROR", false, None);
                Err(e)
            }
            Err(_) => bail!("batch scheduler dropped the request before replying"),
        }
    }

    /// Counter snapshot. The scheduler-wide totals are sums over the
    /// per-lane counters (`sum(lanes.*) == batch.*` by construction).
    pub fn stats(&self) -> BatchStats {
        let (depths, vtags) = {
            let st = self.inner.queue.state.lock().expect("batch queue poisoned");
            let depths: Vec<usize> = (0..st.lanes.num_lanes()).map(|i| st.lanes.len_of(i)).collect();
            let vtags: Vec<u128> = (0..st.lanes.num_lanes()).map(|i| st.lanes.vfinish(i)).collect();
            (depths, vtags)
        };
        let lanes: Vec<LaneStats> = self
            .inner
            .specs
            .iter()
            .zip(&self.inner.counters)
            .enumerate()
            .map(|(i, (spec, c))| LaneStats {
                name: spec.name.clone(),
                weight: spec.weight,
                capacity: spec.capacity,
                queue_depth: depths[i],
                batches: c.batches.get(),
                batched_requests: c.batched_requests.get(),
                max_batch_size: c.max_batch_size.get(),
                shed: c.shed.get(),
                timeouts: c.timeouts.get(),
                served: c.served.get(),
                cold_work: c.cold_work.get(),
                // Virtual finish tag in milli-cost-units (fixed point
                // rescaled); monotone per lane.
                vtime_milli: (vtags[i].saturating_mul(1000) / SCALE) as u64,
            })
            .collect();
        BatchStats {
            batches: lanes.iter().map(|l| l.batches).sum(),
            batched_requests: lanes.iter().map(|l| l.batched_requests).sum(),
            max_batch_size: lanes.iter().map(|l| l.max_batch_size).max().unwrap_or(0),
            shed: lanes.iter().map(|l| l.shed).sum(),
            timeouts: lanes.iter().map(|l| l.timeouts).sum(),
            queue_depth: lanes.iter().map(|l| l.queue_depth).sum(),
            queue_capacity: lanes.iter().map(|l| l.capacity).sum(),
            lanes,
        }
    }

    /// Combined service + batch + server + latency stats (the
    /// protocol's `STATS` response). The `latency` block is present
    /// only when tracing is enabled.
    pub fn stats_json(&self) -> Json {
        let mut j = self.inner.service.stats_json();
        if let Json::Obj(m) = &mut j {
            m.insert("batch".into(), self.stats().to_json());
            m.insert("server".into(), self.server_json());
            if let Some(t) = &self.inner.tracer {
                m.insert("latency".into(), t.latency_json());
            }
        }
        j
    }

    /// Server identity + effective configuration (the `STATS`
    /// response's `server` block): crate version, uptime, start time,
    /// and the tunables the scheduler actually runs with — normalized
    /// lanes included, so a client sees the implicit `default` lane.
    fn server_json(&self) -> Json {
        let opts = &self.inner.opts;
        let trace = &opts.trace;
        let lanes = Json::obj(
            self.inner
                .specs
                .iter()
                .map(|s| {
                    (
                        s.name.as_str(),
                        Json::obj(vec![("weight", Json::int(s.weight)), ("capacity", Json::int(s.capacity))]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("version", Json::str(env!("CARGO_PKG_VERSION"))),
            ("uptime_ms", Json::Num(self.inner.started.elapsed().as_millis() as f64)),
            ("started_at_unix_ms", Json::Num(self.inner.started_unix_ms as f64)),
            (
                "config",
                Json::obj(vec![
                    ("queue_capacity", Json::int(opts.queue_capacity)),
                    ("batch_window_ms", Json::Num(opts.batch_window.as_millis() as f64)),
                    ("max_batch", Json::int(opts.max_batch)),
                    (
                        "policy",
                        Json::str(match opts.policy {
                            AdmissionPolicy::Shed => "shed",
                            AdmissionPolicy::Block => "block",
                        }),
                    ),
                    ("workers", Json::int(self.inner.service.stats().workers)),
                    ("solver_threads", Json::int(crate::tiling::SolverPool::global().threads())),
                    ("lanes", lanes),
                    (
                        "trace",
                        Json::obj(vec![
                            ("enabled", Json::Bool(trace.enabled)),
                            ("trace_cap", Json::int(trace.journal_cap)),
                            ("slowlog_ms", Json::Num(trace.slowlog_ms as f64)),
                            ("slowlog_cap", Json::int(trace.slowlog_cap)),
                        ]),
                    ),
                ]),
            ),
        ])
    }

    /// The request tracer — `None` when tracing is disabled
    /// (`--trace-cap 0`).
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.inner.tracer.as_ref()
    }

    /// Prometheus-style text exposition (the `METRICS` response): every
    /// scalar of [`stats_json`](BatchScheduler::stats_json) flattened
    /// under the `ftl_` prefix, plus the latency histograms emitted
    /// with `lane`/`temp` labels instead of path-mangled names.
    /// Terminated by `# EOF`.
    pub fn metrics_text(&self) -> String {
        let mut samples = expo::flatten("ftl", &self.stats_json(), &["latency"]);
        if let Some(t) = &self.inner.tracer {
            for (i, spec) in self.inner.specs.iter().enumerate() {
                let lane = spec.name.as_str();
                let warm = expo::hist_samples("ftl_latency_us", &[("lane", lane), ("temp", "warm")], t.warm_hist(i));
                let cold = expo::hist_samples("ftl_latency_us", &[("lane", lane), ("temp", "cold")], t.cold_hist(i));
                samples.extend(warm);
                samples.extend(cold);
            }
            samples.extend(expo::hist_samples("ftl_latency_total_us", &[], t.overall()));
            samples.extend(expo::hist_samples("ftl_queue_us", &[], t.queue_hist()));
        }
        expo::render(&samples)
    }

    /// Close the queues, drain what's already admitted, and stop the
    /// dispatcher (also runs on drop). New cold requests are rejected;
    /// fully warm requests may still be served via the cache fast path
    /// (the underlying [`PlanService`] is not shut down).
    pub fn shutdown(&self) {
        {
            let mut st = self.inner.queue.state.lock().expect("batch queue poisoned");
            st.open = false;
        }
        self.inner.queue.not_empty.notify_all();
        self.inner.queue.not_full.notify_all();
        if let Some(handle) = self.dispatcher.lock().expect("batch dispatcher poisoned").take() {
            handle.join().ok();
        }
    }
}

impl Drop for BatchScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Handle one single-JSON-response line of the serve protocol:
///
/// ```text
/// DEPLOY <workload> <soc> <strategy> [deadline-ms] [lane=<name>]
///     -> deploy report JSON + "outcome": "OK", "cached", "sim_cached",
///        "lane", "fingerprint", "trace" (the trace id, when tracing is
///        enabled) — or {"outcome": "SHED"|"TIMEOUT", "lane": ...,
///        "trace": ..., "error": ...} when admission control rejects or
///        the deadline expires. An unknown lane name falls back to the
///        default lane, never an error.
/// STATS -> service + batch counter snapshot (incl. lanes.<name>.*,
///          the "server" identity/config block and, when tracing is
///          enabled, the "latency" histogram block)
/// PING  -> {"pong": true}
/// ```
///
/// Errors never escape: they come back as one `{"error": ...}` object so
/// a bad request can't kill a connection handler. Connection handlers
/// should speak [`handle_command`], which adds the multi-line
/// observability commands (`METRICS`, `TRACE`, `SLOW`) on top of this.
pub fn handle_line(scheduler: &BatchScheduler, line: &str) -> Json {
    match handle_request(scheduler, line) {
        Ok(j) => j,
        Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
    }
}

/// Handle one protocol command — [`handle_line`] plus the multi-line
/// observability commands, the single implementation behind both
/// `ftl serve` and `examples/deploy_server.rs`:
///
/// ```text
/// METRICS   -> Prometheus-style text exposition, "# EOF"-terminated
/// TRACE [n] -> {"spans": N} header + the n newest journal spans as
///              JSON lines, newest first (default 16)
/// SLOW  [n] -> same shape, over-threshold spans from the slowlog
/// ```
///
/// Single-line commands return their JSON object rendered to text;
/// errors stay one `{"error": ...}` object (`TRACE`/`SLOW` with tracing
/// disabled included). The response never carries a trailing newline —
/// connection handlers add their own line termination.
pub fn handle_command(scheduler: &BatchScheduler, line: &str) -> String {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["METRICS"] => scheduler.metrics_text().trim_end().to_string(),
        [cmd @ ("TRACE" | "SLOW"), rest @ ..] if rest.len() <= 1 => {
            let n = match rest {
                [tok] => tok.parse::<usize>().ok(),
                _ => Some(16),
            };
            let (Some(n), Some(tracer)) = (n, scheduler.tracer()) else {
                let msg = match n {
                    None => format!("bad count '{}' in '{line}' (expected a non-negative integer)", rest[0]),
                    Some(_) => "tracing is disabled (--trace-cap 0)".to_string(),
                };
                return Json::obj(vec![("error", Json::str(msg))]).to_string();
            };
            let spans = if *cmd == "TRACE" { tracer.recent(n) } else { tracer.slow(n) };
            tracer.dump(&spans)
        }
        _ => handle_line(scheduler, line).to_string(),
    }
}

fn handle_request(scheduler: &BatchScheduler, line: &str) -> Result<Json> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["DEPLOY", workload, soc, strategy, rest @ ..] if rest.len() <= 2 => {
            let mut deadline: Option<Duration> = None;
            let mut lane: Option<&str> = None;
            for tok in rest {
                if let Some(name) = tok.strip_prefix("lane=") {
                    if lane.replace(name).is_some() {
                        bail!("duplicate lane= field in '{line}'");
                    }
                } else {
                    let ms: u64 = tok
                        .parse()
                        .map_err(|_| anyhow!("bad deadline '{tok}' (expected milliseconds or lane=<name>)"))?;
                    if deadline.replace(Duration::from_millis(ms)).is_some() {
                        bail!("duplicate deadline in '{line}'");
                    }
                }
            }
            deploy_request(scheduler, workload, soc, strategy, deadline, lane)
        }
        ["STATS"] => Ok(scheduler.stats_json()),
        ["PING"] => Ok(Json::obj(vec![("pong", Json::Bool(true))])),
        _ => bail!(
            "bad request: '{line}' (expected: DEPLOY <workload> <soc> <strategy> [deadline-ms] [lane=<name>] \
             | STATS | METRICS | TRACE [n] | SLOW [n] | PING)"
        ),
    }
}

fn deploy_request(
    scheduler: &BatchScheduler,
    workload: &str,
    soc: &str,
    strategy: &str,
    deadline: Option<Duration>,
    lane: Option<&str>,
) -> Result<Json> {
    let strategy = crate::tiling::Strategy::parse(strategy)
        .ok_or_else(|| anyhow!("bad strategy '{strategy}'"))?;
    let graph = resolve_workload(workload)?;
    let cfg = DeployConfig::preset(soc, strategy)?;
    let soc_cfg = cfg.soc.clone();
    let lane_name = scheduler.lane_name(lane).to_string();
    let (outcome, trace_id) = scheduler.deploy_traced(workload, graph, cfg, lane, deadline)?;
    match outcome {
        BatchOutcome::Served(reply) => {
            let mut j = reply.report.to_json(&soc_cfg);
            if let Json::Obj(m) = &mut j {
                m.insert("outcome".into(), Json::str("OK"));
                m.insert("cached".into(), Json::Bool(reply.cached));
                m.insert("sim_cached".into(), Json::Bool(reply.sim_cached));
                m.insert("lane".into(), Json::str(lane_name));
                m.insert("fingerprint".into(), Json::str(reply.fingerprint.hex()));
                if let Some(id) = trace_id {
                    m.insert("trace".into(), Json::Num(id as f64));
                }
            }
            Ok(j)
        }
        BatchOutcome::Shed => {
            let mut fields = vec![
                ("outcome", Json::str("SHED")),
                ("lane", Json::str(lane_name)),
                ("error", Json::str("queue full: request shed by admission control")),
            ];
            if let Some(id) = trace_id {
                fields.push(("trace", Json::Num(id as f64)));
            }
            Ok(Json::obj(fields))
        }
        BatchOutcome::TimedOut => {
            let mut fields = vec![
                ("outcome", Json::str("TIMEOUT")),
                ("lane", Json::str(lane_name)),
                ("error", Json::str("deadline expired before the request was dispatched")),
            ];
            if let Some(id) = trace_id {
                fields.push(("trace", Json::Num(id as f64)));
            }
            Ok(Json::obj(fields))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments;
    use crate::serve::ServeOptions;
    use crate::tiling::Strategy;

    fn small() -> (Graph, DeployConfig) {
        (
            experiments::vit_mlp_stage(16, 24, 48),
            DeployConfig::preset("cluster-only", Strategy::Ftl).unwrap(),
        )
    }

    fn small_service() -> Arc<PlanService> {
        Arc::new(PlanService::new(ServeOptions {
            cache_capacity: 8,
            cache_shards: 2,
            workers: 1,
            ..ServeOptions::default()
        }))
    }

    #[test]
    fn zero_capacity_queue_admits_nothing() {
        for policy in [AdmissionPolicy::Shed, AdmissionPolicy::Block] {
            let sched = BatchScheduler::new(
                small_service(),
                BatchOptions { queue_capacity: 0, policy, ..BatchOptions::default() },
            );
            let (g, c) = small();
            let outcome = sched.deploy("z", g, c).unwrap();
            assert!(matches!(outcome, BatchOutcome::Shed), "zero capacity must shed ({policy:?})");
            assert_eq!(sched.stats().shed, 1);
            assert_eq!(sched.service().stats().requests, 0, "shed requests must not reach the solver");
        }
    }

    #[test]
    fn expired_deadline_times_out_at_enqueue() {
        let sched = BatchScheduler::new(small_service(), BatchOptions::default());
        let (g, c) = small();
        let outcome = sched.deploy_with_deadline("late", g, c, Some(Duration::ZERO)).unwrap();
        assert!(matches!(outcome, BatchOutcome::TimedOut));
        assert_eq!(sched.stats().timeouts, 1);
        assert_eq!(sched.service().stats().requests, 0);
    }

    #[test]
    fn served_outcome_roundtrips_through_protocol() {
        let sched = BatchScheduler::new(
            small_service(),
            BatchOptions { batch_window: Duration::ZERO, ..BatchOptions::default() },
        );
        let j = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl");
        assert!(j.get_opt("error").is_none(), "unexpected error: {j}");
        assert_eq!(j.get("outcome").unwrap().as_str().unwrap(), "OK");
        assert_eq!(j.get("lane").unwrap().as_str().unwrap(), "default");
        assert!(j.get("sim").unwrap().get("total_cycles").unwrap().as_usize().unwrap() > 0);
        // Warm repeat: both caches hit, and the fast path keeps the
        // request out of the batch queue entirely.
        let j2 = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl");
        assert!(j2.get("cached").unwrap().as_bool().unwrap());
        assert!(j2.get("sim_cached").unwrap().as_bool().unwrap());
        let stats = handle_line(&sched, "STATS");
        assert_eq!(stats.get("solves").unwrap().as_usize().unwrap(), 1);
        assert_eq!(stats.get("sims").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            stats.get("batch").unwrap().get("batched_requests").unwrap().as_usize().unwrap(),
            1,
            "the warm repeat must bypass the queue"
        );
        // Per-lane counters ride along under batch.lanes.<name>.*.
        let lane = stats.get("batch").unwrap().get("lanes").unwrap().get("default").unwrap();
        assert_eq!(lane.get("batched_requests").unwrap().as_usize().unwrap(), 1);
        assert_eq!(lane.get("weight").unwrap().as_usize().unwrap(), 1);
        assert!(lane.get("cold_work").unwrap().as_usize().unwrap() >= 1, "the cold deploy must be charged");
    }

    #[test]
    fn protocol_routes_lane_field_and_unknown_lane_falls_back() {
        let sched = BatchScheduler::new(
            small_service(),
            BatchOptions {
                batch_window: Duration::ZERO,
                lanes: vec![LaneSpec::new("gold", 3, 8)],
                ..BatchOptions::default()
            },
        );
        let j = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl lane=gold");
        assert!(j.get_opt("error").is_none(), "unexpected error: {j}");
        assert_eq!(j.get("lane").unwrap().as_str().unwrap(), "gold");
        let j2 = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only baseline lane=no-such-lane");
        assert!(j2.get_opt("error").is_none(), "unknown lane must fall back, not error: {j2}");
        assert_eq!(j2.get("lane").unwrap().as_str().unwrap(), "default");
        // Deadline and lane compose in either order.
        let j3 = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl lane=gold 5000");
        assert!(j3.get_opt("error").is_none(), "{j3}");
        let j4 = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl 5000 lane=gold");
        assert!(j4.get_opt("error").is_none(), "{j4}");
        let batch = sched.stats_json().get("batch").unwrap().clone();
        let gold = batch.get("lanes").unwrap().get("gold").unwrap().clone();
        assert_eq!(gold.get("batched_requests").unwrap().as_usize().unwrap(), 1, "one cold request in gold");
        // Duplicate fields are protocol errors.
        for bad in [
            "DEPLOY vit-tiny-stage cluster-only ftl lane=a lane=b",
            "DEPLOY vit-tiny-stage cluster-only ftl 5 6",
        ] {
            assert!(handle_line(&sched, bad).get_opt("error").is_some(), "'{bad}' must error");
        }
    }

    #[test]
    fn protocol_errors_become_json_not_panics() {
        let sched = BatchScheduler::new(small_service(), BatchOptions::default());
        for bad in [
            "",
            "DEPLOY",
            "DEPLOY x",
            "DEPLOY a b c d e",
            "NOPE x y z",
            "DEPLOY no-such-net siracusa ftl",
            "DEPLOY vit-tiny-stage no-such-soc ftl",
            "DEPLOY vit-tiny-stage siracusa no-such-strategy",
            "DEPLOY vit-tiny-stage siracusa ftl not-a-number",
        ] {
            let j = handle_line(&sched, bad);
            assert!(j.get_opt("error").is_some(), "'{bad}' must yield an error object, got {j}");
        }
        let pong = handle_line(&sched, "PING");
        assert!(pong.get("pong").unwrap().as_bool().unwrap());
        let stats = handle_line(&sched, "STATS");
        assert_eq!(stats.get("solves").unwrap().as_usize().unwrap(), 0);
        assert_eq!(stats.get("batch").unwrap().get("shed").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn metrics_trace_slow_protocol_commands() {
        let sched = BatchScheduler::new(
            small_service(),
            BatchOptions { batch_window: Duration::ZERO, ..BatchOptions::default() },
        );
        let j = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl");
        assert!(j.get_opt("error").is_none(), "{j}");
        assert!(j.get("trace").unwrap().as_u64().unwrap() >= 1, "replies must carry the trace id");
        // METRICS is EOF-terminated and round-trips through the strict
        // exposition parser, cold latency included.
        let metrics = handle_command(&sched, "METRICS");
        assert!(metrics.ends_with("# EOF"), "METRICS must end with the EOF marker");
        let samples = crate::metrics::expo::parse(&metrics).unwrap();
        assert!(
            samples.iter().any(|s| s.name == "ftl_latency_total_us_count" && s.value >= 1.0),
            "the served request must show up in the overall latency histogram"
        );
        // TRACE dumps a {"spans": N} header plus one JSON line per span.
        let trace = handle_command(&sched, "TRACE 8");
        let mut lines = trace.lines();
        let header = crate::util::json::parse(lines.next().unwrap()).unwrap();
        assert!(header.get("spans").unwrap().as_usize().unwrap() >= 1);
        let span = crate::util::json::parse(lines.next().unwrap()).unwrap();
        assert_eq!(span.get("outcome").unwrap().as_str().unwrap(), "OK");
        // STATS grows the server identity and latency blocks.
        let stats = handle_line(&sched, "STATS");
        let server = stats.get("server").unwrap();
        assert_eq!(server.get("version").unwrap().as_str().unwrap(), env!("CARGO_PKG_VERSION"));
        assert!(server.get("config").unwrap().get("lanes").unwrap().get("default").is_ok());
        let overall = stats.get("latency").unwrap().get("overall").unwrap();
        assert!(overall.get("count").unwrap().as_u64().unwrap() >= 1);
        // SLOW parses even when empty; a disabled tracer yields an
        // error object (and no latency block), never a panic.
        let slow = handle_command(&sched, "SLOW");
        let slow_header = crate::util::json::parse(slow.lines().next().unwrap()).unwrap();
        assert!(slow_header.get("spans").is_ok());
        let off = BatchScheduler::new(
            small_service(),
            BatchOptions { trace: TraceOptions::disabled(), ..BatchOptions::default() },
        );
        let denied = handle_command(&off, "TRACE");
        assert!(crate::util::json::parse(&denied).unwrap().get("error").is_ok());
        assert!(handle_line(&off, "STATS").get_opt("latency").is_none());
        assert!(handle_command(&off, "TRACE nope").contains("error"));
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let sched = BatchScheduler::new(small_service(), BatchOptions::default());
        sched.shutdown();
        let (g, c) = small();
        assert!(sched.deploy("late", g, c).is_err());
    }
}
