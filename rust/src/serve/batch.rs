//! [`BatchScheduler`] — traffic shaping in front of [`PlanService`].
//!
//! The plan cache and single-flight layer (PR 1) make *identical*
//! concurrent requests cheap, but under heavy traffic the serve layer
//! still drained its queue one request at a time with no backpressure —
//! the paper's off-chip-bottleneck shape, moved up into the deployment
//! service. This module adds the missing traffic controls:
//!
//! * **Admission control** — a bounded queue with a configurable
//!   capacity and a full-queue policy: [`AdmissionPolicy::Shed`] rejects
//!   immediately (the request resolves to [`BatchOutcome::Shed`], the
//!   protocol's `SHED`), [`AdmissionPolicy::Block`] applies backpressure
//!   by parking the submitter until space frees up. Requests may carry a
//!   deadline; one that expires before dispatch resolves to
//!   [`BatchOutcome::TimedOut`] (`TIMEOUT`) instead of doing dead work.
//! * **SoC-grouped batching** — the dispatcher collects requests for a
//!   short window, sorts the batch by SoC fingerprint (then full plan
//!   fingerprint), and walks it in runs: requests targeting the same SoC
//!   are solved back-to-back so the solver and cost models stay warm,
//!   and each run of *identical* fingerprints is solved and simulated
//!   **once**, with the result fanned out to every waiter in the run.
//!
//! Batching composes with (rather than replaces) the caches underneath:
//! a fully warm request short-circuits into the caches without ever
//! entering the queue (batching only exists to amortize cold work),
//! fan-out handles identical requests *within* a batch, the plan + sim
//! caches handle repeats *across* batches, and single-flight handles
//! races between parallel dispatch lanes, fast-path callers and sync
//! callers. Within a batch, each distinct SoC gets its own dispatch
//! lane: same-SoC groups solve back-to-back for locality, distinct SoCs
//! solve in parallel.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::DeployConfig;
use crate::ir::Graph;
use crate::metrics::BatchStats;
use crate::util::json::Json;

use super::fingerprint::{fingerprint, soc_fingerprint, Fingerprint};
use super::service::{resolve_workload, PlanService, ServeReply};

/// What admission control does with a new request when the queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Reject immediately — the request resolves to [`BatchOutcome::Shed`].
    Shed,
    /// Apply backpressure — park the submitting thread until space frees.
    #[default]
    Block,
}

/// Tunables for a [`BatchScheduler`].
#[derive(Debug, Clone, Copy)]
pub struct BatchOptions {
    /// Bounded-queue capacity. **Zero admits nothing**: every request is
    /// shed regardless of policy (blocking on a queue that can never
    /// drain would deadlock the submitter).
    pub queue_capacity: usize,
    /// How long the dispatcher holds a batch open after the first
    /// request arrives, letting the queue fill so grouping has something
    /// to group. Zero dispatches whatever is queued immediately.
    pub batch_window: Duration,
    /// Max requests per dispatched batch (clamped to `>= 1`).
    pub max_batch: usize,
    /// Full-queue policy.
    pub policy: AdmissionPolicy,
}

impl Default for BatchOptions {
    fn default() -> Self {
        Self {
            queue_capacity: 256,
            batch_window: Duration::from_millis(2),
            max_batch: 64,
            policy: AdmissionPolicy::Block,
        }
    }
}

/// Terminal outcome of one batched request.
pub enum BatchOutcome {
    /// Deployed — possibly via batch fan-out or the caches.
    Served(Box<ServeReply>),
    /// Rejected by admission control (full queue, shed policy).
    Shed,
    /// Deadline expired before the request was dispatched.
    TimedOut,
}

impl BatchOutcome {
    /// The reply, if the request was served.
    pub fn served(self) -> Option<ServeReply> {
        match self {
            BatchOutcome::Served(reply) => Some(*reply),
            _ => None,
        }
    }

    /// Protocol rendering of the outcome kind (`OK` / `SHED` / `TIMEOUT`).
    pub fn kind(&self) -> &'static str {
        match self {
            BatchOutcome::Served(_) => "OK",
            BatchOutcome::Shed => "SHED",
            BatchOutcome::TimedOut => "TIMEOUT",
        }
    }
}

/// One admitted request waiting in the queue.
struct Pending {
    workload: String,
    graph: Graph,
    config: DeployConfig,
    /// Full plan fingerprint — the fan-out key.
    key: Fingerprint,
    /// SoC-structure fingerprint — the batch grouping key.
    soc_key: Fingerprint,
    /// Absolute dispatch deadline, if the request carries one.
    deadline: Option<Instant>,
    reply: mpsc::Sender<Result<BatchOutcome>>,
}

/// How admission control resolved an enqueue attempt.
enum Admit {
    Admitted,
    Shed,
    /// The request's deadline expired while its submitter was parked
    /// waiting for queue space (Block policy only).
    Expired,
    Closed,
}

struct QueueState {
    items: VecDeque<Pending>,
    open: bool,
}

struct Queue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

/// State shared between the facade and the dispatcher thread.
struct BatchInner {
    service: Arc<PlanService>,
    opts: BatchOptions,
    queue: Queue,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    max_batch_size: AtomicU64,
    shed: AtomicU64,
    timeouts: AtomicU64,
}

impl BatchInner {
    /// Admission control: bounded enqueue honouring the full-queue policy.
    /// A blocked submitter's deadline keeps ticking: the park is bounded
    /// by it, so a deadlined request can never be stalled unboundedly by
    /// backpressure.
    fn enqueue(&self, pending: Pending) -> Admit {
        let deadline = pending.deadline;
        let mut st = self.queue.state.lock().expect("batch queue poisoned");
        loop {
            if !st.open {
                return Admit::Closed;
            }
            if self.opts.queue_capacity == 0 {
                // A queue that can never drain must not block (see
                // `BatchOptions::queue_capacity`).
                self.shed.fetch_add(1, Ordering::Relaxed);
                return Admit::Shed;
            }
            if st.items.len() < self.opts.queue_capacity {
                st.items.push_back(pending);
                self.queue.not_empty.notify_one();
                return Admit::Admitted;
            }
            match self.opts.policy {
                AdmissionPolicy::Shed => {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                    return Admit::Shed;
                }
                AdmissionPolicy::Block => match deadline {
                    None => {
                        st = self.queue.not_full.wait(st).expect("batch queue poisoned");
                    }
                    Some(d) => {
                        let now = Instant::now();
                        if d <= now {
                            self.timeouts.fetch_add(1, Ordering::Relaxed);
                            return Admit::Expired;
                        }
                        let (guard, _) = self
                            .queue
                            .not_full
                            .wait_timeout(st, d - now)
                            .expect("batch queue poisoned");
                        st = guard;
                    }
                },
            }
        }
    }

    /// Dispatcher side: wait for the first request, hold the batch window
    /// open, then drain up to `max_batch` requests. Returns an empty
    /// batch only when the scheduler is shut down and fully drained.
    fn collect(&self) -> Vec<Pending> {
        let mut st = self.queue.state.lock().expect("batch queue poisoned");
        while st.items.is_empty() {
            if !st.open {
                return Vec::new();
            }
            st = self.queue.not_empty.wait(st).expect("batch queue poisoned");
        }
        let window = self.opts.batch_window;
        let max_batch = self.opts.max_batch.max(1);
        let t0 = Instant::now();
        while st.open && st.items.len() < max_batch {
            let elapsed = t0.elapsed();
            if elapsed >= window {
                break;
            }
            let (guard, _) = self
                .queue
                .not_empty
                .wait_timeout(st, window - elapsed)
                .expect("batch queue poisoned");
            st = guard;
        }
        let n = st.items.len().min(max_batch);
        let batch: Vec<Pending> = st.items.drain(..n).collect();
        drop(st);
        self.queue.not_full.notify_all();
        batch
    }

    /// Dispatch one batch: group, deduplicate, solve-or-hit once per
    /// distinct fingerprint, fan out.
    fn dispatch(&self, mut batch: Vec<Pending>) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
        self.max_batch_size.fetch_max(batch.len() as u64, Ordering::Relaxed);
        // SoC-major order keeps the solver's working set warm across
        // consecutive groups; full-fingerprint order inside a SoC makes
        // identical requests adjacent for the run-length walk below.
        batch.sort_by_key(|p| (p.soc_key, p.key));
        let mut groups: Vec<Vec<Pending>> = Vec::new();
        for p in batch {
            let start_new = groups.last().map_or(true, |g| g[0].key != p.key);
            if start_new {
                groups.push(Vec::new());
            }
            groups.last_mut().expect("group pushed above").push(p);
        }
        // One lane per distinct SoC: lanes run in parallel so
        // distinct-SoC solves don't serialize behind each other, and
        // *within* a lane the distinct-fingerprint groups fan out over
        // the shared solver pool ([`crate::tiling::SolverPool`]) — one
        // batch's distinct cold requests solve concurrently, bounded by
        // the pool's global worker budget (which the per-group
        // branch-and-bound also draws from, so nesting degrades to fewer
        // workers per solve instead of oversubscribing).
        let mut lanes: Vec<Vec<Vec<Pending>>> = Vec::new();
        let mut last_soc: Option<Fingerprint> = None;
        for group in groups {
            let soc = group[0].soc_key;
            if last_soc != Some(soc) {
                lanes.push(Vec::new());
                last_soc = Some(soc);
            }
            lanes.last_mut().expect("lane pushed above").push(group);
        }
        let pool = crate::tiling::SolverPool::global();
        if lanes.len() == 1 {
            pool.map(lanes.remove(0), |group| self.dispatch_group(group));
            return;
        }
        std::thread::scope(|s| {
            for lane in lanes {
                s.spawn(move || {
                    pool.map(lane, |group| self.dispatch_group(group));
                });
            }
        });
    }

    /// One solve + one simulation for a run of identical fingerprints;
    /// every waiter gets a reply carrying its own workload label.
    fn dispatch_group(&self, group: Vec<Pending>) {
        let now = Instant::now();
        let (live, expired): (Vec<Pending>, Vec<Pending>) =
            group.into_iter().partition(|p| p.deadline.map_or(true, |d| d > now));
        for p in expired {
            self.timeouts.fetch_add(1, Ordering::Relaxed);
            p.reply.send(Ok(BatchOutcome::TimedOut)).ok();
        }
        let mut live = live.into_iter();
        let Some(leader) = live.next() else { return };
        // Panic isolation: a panicking solve must kill neither the
        // dispatcher nor the waiters parked on their reply channels.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.service.deploy(&leader.workload, &leader.graph, &leader.config)
        }))
        .unwrap_or_else(|_| {
            Err(anyhow!("batch dispatcher panicked while deploying '{}'", leader.workload))
        });
        match result {
            Ok(reply) => {
                for p in live {
                    // Fan-out: share the plan and the simulation, rebuild
                    // only the cheap per-request report wrapper.
                    let report = reply.plan.report_with_sim(&p.workload, &p.config, reply.report.sim.clone());
                    let fanned = ServeReply {
                        plan: reply.plan.clone(),
                        report,
                        fingerprint: reply.fingerprint,
                        cached: true,
                        sim_cached: true,
                    };
                    p.reply.send(Ok(BatchOutcome::Served(Box::new(fanned)))).ok();
                }
                leader.reply.send(Ok(BatchOutcome::Served(Box::new(reply)))).ok();
            }
            Err(e) => {
                // anyhow::Error is not Clone; re-render the chain per waiter.
                let msg = format!("{e:#}");
                for p in live.chain(std::iter::once(leader)) {
                    p.reply.send(Err(anyhow!("batched deploy failed: {msg}"))).ok();
                }
            }
        }
    }
}

/// The batching scheduler (see module docs). Request lifecycle:
/// **admit** (bounded queue) → **batch** (window + SoC grouping) →
/// **solve-or-hit** (plan cache) → **simulate-or-hit** (sim cache) →
/// **reply** (fan-out to every waiter of the fingerprint).
pub struct BatchScheduler {
    inner: Arc<BatchInner>,
    dispatcher: Mutex<Option<JoinHandle<()>>>,
}

impl BatchScheduler {
    /// Start a scheduler in front of `service` (spawns the dispatcher).
    pub fn new(service: Arc<PlanService>, opts: BatchOptions) -> Self {
        let inner = Arc::new(BatchInner {
            service,
            opts,
            queue: Queue {
                state: Mutex::new(QueueState { items: VecDeque::new(), open: true }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
            },
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            max_batch_size: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
        });
        let worker = inner.clone();
        let handle = std::thread::Builder::new()
            .name("ftl-batch-dispatch".into())
            .spawn(move || loop {
                let batch = worker.collect();
                if batch.is_empty() {
                    break;
                }
                worker.dispatch(batch);
            })
            .expect("spawn batch dispatcher");
        Self { inner, dispatcher: Mutex::new(Some(handle)) }
    }

    /// Scheduler with default tunables over a default service.
    pub fn with_defaults() -> Self {
        Self::new(Arc::new(PlanService::with_defaults()), BatchOptions::default())
    }

    /// The service behind the scheduler (for direct/sync callers and
    /// counter assertions).
    pub fn service(&self) -> &Arc<PlanService> {
        &self.inner.service
    }

    /// Blocking batched deployment without a deadline.
    pub fn deploy(&self, workload: &str, graph: Graph, config: DeployConfig) -> Result<BatchOutcome> {
        self.deploy_with_deadline(workload, graph, config, None)
    }

    /// Blocking batched deployment. `deadline` bounds how long the
    /// request may wait *before dispatch* — including time parked on a
    /// full queue under [`AdmissionPolicy::Block`]; a request whose
    /// deadline passes first resolves to [`BatchOutcome::TimedOut`]
    /// without consuming solver time. A deadline of zero is already
    /// expired at enqueue.
    pub fn deploy_with_deadline(
        &self,
        workload: &str,
        graph: Graph,
        config: DeployConfig,
        deadline: Option<Duration>,
    ) -> Result<BatchOutcome> {
        if let Some(d) = deadline {
            if d.is_zero() {
                self.inner.timeouts.fetch_add(1, Ordering::Relaxed);
                return Ok(BatchOutcome::TimedOut);
            }
        }
        // Warm fast path: a fully cached request skips the queue and the
        // batch window entirely — batching only exists to amortize cold
        // work, and the caches + single-flight below stay coherent with
        // the dispatcher regardless of which path a request takes.
        if let Some(result) = self.inner.service.deploy_if_warm(workload, &graph, &config) {
            return result.map(|reply| BatchOutcome::Served(Box::new(reply)));
        }
        let key = fingerprint(&graph, &config);
        let soc_key = soc_fingerprint(&config.soc);
        let (tx, rx) = mpsc::channel();
        let pending = Pending {
            workload: workload.to_string(),
            graph,
            config,
            key,
            soc_key,
            deadline: deadline.map(|d| Instant::now() + d),
            reply: tx,
        };
        match self.inner.enqueue(pending) {
            Admit::Admitted => {}
            Admit::Shed => return Ok(BatchOutcome::Shed),
            Admit::Expired => return Ok(BatchOutcome::TimedOut),
            Admit::Closed => bail!("batch scheduler is shut down"),
        }
        match rx.recv() {
            Ok(outcome) => outcome,
            Err(_) => bail!("batch scheduler dropped the request before replying"),
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> BatchStats {
        BatchStats {
            batches: self.inner.batches.load(Ordering::Relaxed),
            batched_requests: self.inner.batched_requests.load(Ordering::Relaxed),
            max_batch_size: self.inner.max_batch_size.load(Ordering::Relaxed),
            shed: self.inner.shed.load(Ordering::Relaxed),
            timeouts: self.inner.timeouts.load(Ordering::Relaxed),
            queue_depth: self.inner.queue.state.lock().expect("batch queue poisoned").items.len(),
            queue_capacity: self.inner.opts.queue_capacity,
        }
    }

    /// Combined service + batch stats (the protocol's `STATS` response).
    pub fn stats_json(&self) -> Json {
        let mut j = self.inner.service.stats_json();
        if let Json::Obj(m) = &mut j {
            m.insert("batch".into(), self.stats().to_json());
        }
        j
    }

    /// Close the queue, drain what's already admitted, and stop the
    /// dispatcher (also runs on drop). New cold requests are rejected;
    /// fully warm requests may still be served via the cache fast path
    /// (the underlying [`PlanService`] is not shut down).
    pub fn shutdown(&self) {
        {
            let mut st = self.inner.queue.state.lock().expect("batch queue poisoned");
            st.open = false;
        }
        self.inner.queue.not_empty.notify_all();
        self.inner.queue.not_full.notify_all();
        if let Some(handle) = self.dispatcher.lock().expect("batch dispatcher poisoned").take() {
            handle.join().ok();
        }
    }
}

impl Drop for BatchScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Handle one line of the serve protocol — the single implementation
/// behind both `ftl serve` and `examples/deploy_server.rs`:
///
/// ```text
/// DEPLOY <workload> <soc> <strategy> [deadline-ms]
///     -> deploy report JSON + "outcome": "OK", "cached", "sim_cached",
///        "fingerprint" — or {"outcome": "SHED"|"TIMEOUT", "error": ...}
///        when admission control rejects or the deadline expires
/// STATS -> service + batch counter snapshot
/// PING  -> {"pong": true}
/// ```
///
/// Errors never escape: they come back as one `{"error": ...}` object so
/// a bad request can't kill a connection handler.
pub fn handle_line(scheduler: &BatchScheduler, line: &str) -> Json {
    match handle_request(scheduler, line) {
        Ok(j) => j,
        Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
    }
}

fn handle_request(scheduler: &BatchScheduler, line: &str) -> Result<Json> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts.as_slice() {
        ["DEPLOY", workload, soc, strategy] => deploy_request(scheduler, workload, soc, strategy, None),
        ["DEPLOY", workload, soc, strategy, deadline_ms] => {
            let ms: u64 = deadline_ms
                .parse()
                .map_err(|_| anyhow!("bad deadline '{deadline_ms}' (expected milliseconds)"))?;
            deploy_request(scheduler, workload, soc, strategy, Some(Duration::from_millis(ms)))
        }
        ["STATS"] => Ok(scheduler.stats_json()),
        ["PING"] => Ok(Json::obj(vec![("pong", Json::Bool(true))])),
        _ => bail!(
            "bad request: '{line}' (expected: DEPLOY <workload> <soc> <strategy> [deadline-ms] | STATS | PING)"
        ),
    }
}

fn deploy_request(
    scheduler: &BatchScheduler,
    workload: &str,
    soc: &str,
    strategy: &str,
    deadline: Option<Duration>,
) -> Result<Json> {
    let strategy = crate::tiling::Strategy::parse(strategy)
        .ok_or_else(|| anyhow!("bad strategy '{strategy}'"))?;
    let graph = resolve_workload(workload)?;
    let cfg = DeployConfig::preset(soc, strategy)?;
    let soc_cfg = cfg.soc.clone();
    let outcome = scheduler.deploy_with_deadline(workload, graph, cfg, deadline)?;
    match outcome {
        BatchOutcome::Served(reply) => {
            let mut j = reply.report.to_json(&soc_cfg);
            if let Json::Obj(m) = &mut j {
                m.insert("outcome".into(), Json::str("OK"));
                m.insert("cached".into(), Json::Bool(reply.cached));
                m.insert("sim_cached".into(), Json::Bool(reply.sim_cached));
                m.insert("fingerprint".into(), Json::str(reply.fingerprint.hex()));
            }
            Ok(j)
        }
        BatchOutcome::Shed => Ok(Json::obj(vec![
            ("outcome", Json::str("SHED")),
            ("error", Json::str("queue full: request shed by admission control")),
        ])),
        BatchOutcome::TimedOut => Ok(Json::obj(vec![
            ("outcome", Json::str("TIMEOUT")),
            ("error", Json::str("deadline expired before the request was dispatched")),
        ])),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments;
    use crate::serve::ServeOptions;
    use crate::tiling::Strategy;

    fn small() -> (Graph, DeployConfig) {
        (
            experiments::vit_mlp_stage(16, 24, 48),
            DeployConfig::preset("cluster-only", Strategy::Ftl).unwrap(),
        )
    }

    fn small_service() -> Arc<PlanService> {
        Arc::new(PlanService::new(ServeOptions {
            cache_capacity: 8,
            cache_shards: 2,
            workers: 1,
            ..ServeOptions::default()
        }))
    }

    #[test]
    fn zero_capacity_queue_admits_nothing() {
        for policy in [AdmissionPolicy::Shed, AdmissionPolicy::Block] {
            let sched = BatchScheduler::new(
                small_service(),
                BatchOptions { queue_capacity: 0, policy, ..BatchOptions::default() },
            );
            let (g, c) = small();
            let outcome = sched.deploy("z", g, c).unwrap();
            assert!(matches!(outcome, BatchOutcome::Shed), "zero capacity must shed ({policy:?})");
            assert_eq!(sched.stats().shed, 1);
            assert_eq!(sched.service().stats().requests, 0, "shed requests must not reach the solver");
        }
    }

    #[test]
    fn expired_deadline_times_out_at_enqueue() {
        let sched = BatchScheduler::new(small_service(), BatchOptions::default());
        let (g, c) = small();
        let outcome = sched.deploy_with_deadline("late", g, c, Some(Duration::ZERO)).unwrap();
        assert!(matches!(outcome, BatchOutcome::TimedOut));
        assert_eq!(sched.stats().timeouts, 1);
        assert_eq!(sched.service().stats().requests, 0);
    }

    #[test]
    fn served_outcome_roundtrips_through_protocol() {
        let sched = BatchScheduler::new(
            small_service(),
            BatchOptions { batch_window: Duration::ZERO, ..BatchOptions::default() },
        );
        let j = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl");
        assert!(j.get_opt("error").is_none(), "unexpected error: {j}");
        assert_eq!(j.get("outcome").unwrap().as_str().unwrap(), "OK");
        assert!(j.get("sim").unwrap().get("total_cycles").unwrap().as_usize().unwrap() > 0);
        // Warm repeat: both caches hit, and the fast path keeps the
        // request out of the batch queue entirely.
        let j2 = handle_line(&sched, "DEPLOY vit-tiny-stage cluster-only ftl");
        assert!(j2.get("cached").unwrap().as_bool().unwrap());
        assert!(j2.get("sim_cached").unwrap().as_bool().unwrap());
        let stats = handle_line(&sched, "STATS");
        assert_eq!(stats.get("solves").unwrap().as_usize().unwrap(), 1);
        assert_eq!(stats.get("sims").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            stats.get("batch").unwrap().get("batched_requests").unwrap().as_usize().unwrap(),
            1,
            "the warm repeat must bypass the queue"
        );
    }

    #[test]
    fn protocol_errors_become_json_not_panics() {
        let sched = BatchScheduler::new(small_service(), BatchOptions::default());
        for bad in [
            "",
            "DEPLOY",
            "DEPLOY x",
            "DEPLOY a b c d e",
            "NOPE x y z",
            "DEPLOY no-such-net siracusa ftl",
            "DEPLOY vit-tiny-stage no-such-soc ftl",
            "DEPLOY vit-tiny-stage siracusa no-such-strategy",
            "DEPLOY vit-tiny-stage siracusa ftl not-a-number",
        ] {
            let j = handle_line(&sched, bad);
            assert!(j.get_opt("error").is_some(), "'{bad}' must yield an error object, got {j}");
        }
        let pong = handle_line(&sched, "PING");
        assert!(pong.get("pong").unwrap().as_bool().unwrap());
        let stats = handle_line(&sched, "STATS");
        assert_eq!(stats.get("solves").unwrap().as_usize().unwrap(), 0);
        assert_eq!(stats.get("batch").unwrap().get("shed").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let sched = BatchScheduler::new(small_service(), BatchOptions::default());
        sched.shutdown();
        let (g, c) = small();
        assert!(sched.deploy("late", g, c).is_err());
    }
}
