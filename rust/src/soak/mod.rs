//! `ftl soak` — seeded soak/chaos harness for the serving stack.
//!
//! Everything the serving layers promise individually (admission
//! control, WFQ lanes, streaming v1 + legacy v0 framing, bounded write
//! queues, write-behind snapshots, torn-tail recovery, counter
//! invariants) is unit- and property-tested in isolation. This module
//! is the end-to-end exercise: it owns a real `ftl serve` *process*,
//! drives seeded mixed traffic at it over real TCP
//! ([`crate::serve::wave::seeded_wire_wave`]), injects the faults an
//! operator actually sees — SIGKILL mid-write-behind, flipped snapshot
//! bytes, garbage envelope files, lane saturation bursts, clients that
//! stop reading, oversized frames — and after every wave scrapes
//! `STATS` over the wire and asserts the cross-counter invariants that
//! must survive all of it:
//!
//! * scheduler totals equal the per-lane sums (`batch.* == Σ lanes.*`);
//! * the solver's search accounting balances
//!   (`scored + capacity_pruned + bound_pruned == space`);
//! * the per-lane warm/cold latency histograms merge to the
//!   scheduler-wide one, and every trace span that starts finishes;
//! * the front door's connection accounting balances
//!   (`open == accepted − closed`) and nothing drifts when faults drop
//!   completions;
//! * persistence never reports write errors or version skips, a
//!   SIGKILL never leaves a torn entry behind (atomic tmp+fsync+rename
//!   writes), a warm restart loads exactly what was settled on disk
//!   with **zero** solver or simulator work on replay, and an injected
//!   corruption is *counted and skipped* — exactly one re-solve, never
//!   a crash or a wrong answer.
//!
//! The wave/fault *schedule* — workloads, dims, lanes, deadlines,
//! protocol mix, which fault fires when, when restarts happen — is a
//! pure function of `--seed`. Outcomes and latencies are not: admission
//! control is real, so a request can shed under load and drop out of
//! the warm pool for later waves. Throughput/latency trajectories land
//! in `BENCH_soak.json` (`--out`) so future re-anchors see the curve.
//!
//! Wave skeleton (`--waves`, minimum 3):
//!
//! ```text
//! wave 1   mixed traffic + gold-lane saturation burst (shed ≥ 1)
//!   settle snapshots → SIGKILL → respawn (fresh port, same dir)
//!   assert: loaded == everything settled, zero corrupt entries
//! wave 2   pure warm replay: all OK, all cached, solves == sims == 0
//!          + slow-reader shed + oversized-frame fault
//!   settle → SIGKILL → flip one plan entry byte + drop a garbage
//!   envelope → respawn
//!   assert: skipped_corrupt == 2, loaded == settled − 1
//! wave 3   warm replay with one hole: exactly one re-solve, sims == 0
//! wave 4+  mixed churn + a seeded fault each; coin-flip kill/restart
//! ```
//!
//! `FTL_SOAK_SMOKE=1` (the CI `soak-smoke` step) shrinks the request
//! volume without changing the skeleton, so the kill/corrupt/replay
//! path runs end-to-end on every push.

#![forbid(unsafe_code)]

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::serve::segment;
use crate::serve::wave::{seeded_wire_wave, WireClient, WireMix, WireWaveReport};
use crate::util::json::Json;
use crate::util::prop::Rng;

/// Configuration for one soak run ([`run`]).
#[derive(Debug, Clone)]
pub struct SoakOptions {
    /// Seed for the traffic/fault schedule — the schedule is a pure
    /// function of it (wire timings and latencies are not).
    pub seed: u64,
    /// Total waves (≥ 3: mixed, warm replay, post-corruption replay;
    /// further waves churn with rotating faults and seeded restarts).
    pub waves: usize,
    /// Requests per wave.
    pub requests_per_wave: usize,
    /// The `ftl` binary to spawn as the server under test.
    pub server_bin: PathBuf,
    /// Snapshot directory shared by every server incarnation.
    pub cache_dir: PathBuf,
    /// Where the trajectory report lands.
    pub out_path: PathBuf,
    /// Smoke mode (`FTL_SOAK_SMOKE=1`): same skeleton, smaller volumes.
    pub smoke: bool,
}

/// Ask the kernel for a free port, then release it for the child. A
/// fresh port per respawn sidesteps both the bind race and the old
/// port lingering in TIME_WAIT after a SIGKILL.
fn free_port() -> Result<u16> {
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    Ok(listener.local_addr()?.port())
}

/// One `ftl serve` incarnation owned by the harness. Dropping it
/// SIGKILLs the child — the harness never shuts a server down
/// gracefully, so every generation change exercises the crash path.
struct Server {
    child: Child,
    addr: String,
}

impl Server {
    /// Spawn `ftl serve` on a fresh port over the shared cache dir and
    /// block until it answers `PING`. Small windows and a fast
    /// snapshot interval keep the soak tight; the raised cache caps
    /// keep the LRU (and the loader's capacity cut at warm start) from
    /// evicting the warm set mid-run, which would silently void the
    /// zero-solve replay asserts.
    fn spawn(opts: &SoakOptions) -> Result<Server> {
        let addr = format!("127.0.0.1:{}", free_port()?);
        let child = Command::new(&opts.server_bin)
            .arg("serve")
            .args(["--addr", addr.as_str()])
            .arg("--cache-dir")
            .arg(&opts.cache_dir)
            .args(["--snapshot-interval-ms", "50"])
            .args(["--batch-window-ms", "5"])
            .args(["--cache-cap", "512"])
            .args(["--sim-cache-cap", "1024"])
            .args(["--write-queue-cap", "1048576"])
            .args(["--trace-cap", "256"])
            .args(["--lane", "gold:3:6:shed"])
            .args(["--lane", "free:1:64"])
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning {} serve", opts.server_bin.display()))?;
        let mut server = Server { child, addr };
        server.wait_ready()?;
        Ok(server)
    }

    fn wait_ready(&mut self) -> Result<()> {
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            if let Some(status) = self.child.try_wait()? {
                bail!("server exited before becoming ready: {status}");
            }
            if let Ok(mut c) = WireClient::connect(&self.addr) {
                if let Ok(j) = c.roundtrip("PING") {
                    if j.get_opt("pong").is_some() {
                        return Ok(());
                    }
                }
            }
            ensure!(Instant::now() < deadline, "server at {} not ready within 60s", self.addr);
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// SIGKILL + reap: no graceful shutdown, no final flush — exactly
    /// the crash the atomic snapshot writes must survive.
    fn kill(mut self) -> Result<()> {
        self.child.kill().context("killing server")?;
        self.child.wait().context("reaping server")?;
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// One `STATS` scrape over a fresh connection.
fn scrape_stats(addr: &str) -> Result<Json> {
    WireClient::connect(addr)?.roundtrip("STATS")
}

/// Read a non-negative integer at a nested `STATS` path.
fn num(j: &Json, path: &[&str]) -> Result<u64> {
    let mut cur = j;
    for key in path {
        cur = cur.get(key).with_context(|| format!("STATS path .{}", path.join(".")))?;
    }
    cur.as_u64().with_context(|| format!("STATS path .{}", path.join(".")))
}

/// Poll `STATS` until the stack is quiescent — empty queues, every
/// trace span finished, request totals stable across two polls — and
/// return the final scrape. Counter identities only bind at rest.
fn quiesce(addr: &str) -> Result<Json> {
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut last: Option<u64> = None;
    loop {
        let stats = scrape_stats(addr)?;
        let depth = num(&stats, &["batch", "queue_depth"])?;
        let spans_balanced = match stats.get_opt("latency") {
            Some(lat) => num(lat, &["spans"])? == num(lat, &["spans_finished"])?,
            None => true,
        };
        let total = num(&stats, &["batch", "batched_requests"])?;
        if depth == 0 && spans_balanced && last == Some(total) {
            return Ok(stats);
        }
        last = Some(total);
        ensure!(Instant::now() < deadline, "server at {addr} failed to quiesce within 60s");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Poll until the write-behind snapshotter has settled — at least one
/// snapshot pass and `entries_written` stable across two polls — and
/// return `loaded + entries_written`: the live entry count a clean
/// reload of the directory must reproduce.
fn settle_persist(addr: &str) -> Result<u64> {
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut last: Option<u64> = None;
    loop {
        let stats = scrape_stats(addr)?;
        let written = num(&stats, &["persist", "entries_written"])?;
        if num(&stats, &["persist", "snapshots"])? >= 1 && last == Some(written) {
            return Ok(num(&stats, &["persist", "loaded"])? + written);
        }
        last = Some(written);
        ensure!(Instant::now() < deadline, "snapshotter at {addr} failed to settle within 60s");
        std::thread::sleep(Duration::from_millis(150));
    }
}

/// Assert every cross-counter invariant the serving stack promises
/// over one *quiesced* `STATS` scrape; returns how many were checked.
fn check_invariants(stats: &Json) -> Result<u64> {
    let mut checked = 0u64;
    // Scheduler totals equal the per-lane sums.
    let batch = stats.get("batch")?;
    let lanes = match batch.get("lanes")? {
        Json::Obj(m) => m,
        other => bail!("batch.lanes must be an object, got {other}"),
    };
    for key in ["batches", "batched_requests", "shed", "timeouts"] {
        let total = num(batch, &[key])?;
        let sum = lanes.values().try_fold(0u64, |acc, l| num(l, &[key]).map(|v| acc + v))?;
        ensure!(total == sum, "batch.{key} {total} != per-lane sum {sum}");
        checked += 1;
    }
    // The branch-and-bound search accounting balances (quiesced).
    let solver = stats.get("solver")?;
    let space = num(solver, &["space"])?;
    let accounted =
        num(solver, &["scored"])? + num(solver, &["capacity_pruned"])? + num(solver, &["bound_pruned"])?;
    ensure!(accounted == space, "solver accounting: scored+pruned {accounted} != space {space}");
    checked += 1;
    // Per-lane warm/cold latency histograms merge to the overall one,
    // and every span that started has finished.
    if let Some(lat) = stats.get_opt("latency") {
        let overall = num(lat, &["overall", "count"])?;
        let lat_lanes = match lat.get("lanes")? {
            Json::Obj(m) => m,
            other => bail!("latency.lanes must be an object, got {other}"),
        };
        let merged = lat_lanes.values().try_fold(0u64, |acc, l| {
            Ok::<u64, anyhow::Error>(acc + num(l, &["warm", "count"])? + num(l, &["cold", "count"])?)
        })?;
        ensure!(merged == overall, "latency merge: lane histograms count {merged} != overall {overall}");
        checked += 1;
        let (spans, finished) = (num(lat, &["spans"])?, num(lat, &["spans_finished"])?);
        ensure!(spans == finished, "span leak: {spans} started, {finished} finished");
        checked += 1;
    }
    // Front-door connection accounting balances.
    if let Some(fe) = stats.get_opt("frontend") {
        let (accepted, closed, open) =
            (num(fe, &["accepted"])?, num(fe, &["closed"])?, num(fe, &["open"])?);
        ensure!(
            open == accepted.saturating_sub(closed),
            "frontend: open {open} != accepted {accepted} - closed {closed}"
        );
        checked += 1;
    }
    // Service-level sanity: nothing errored, caches within capacity.
    ensure!(num(stats, &["errors"])? == 0, "service errors must stay zero under well-formed traffic");
    checked += 1;
    for cache in ["plan_cache", "sim_cache"] {
        let (entries, cap) = (num(stats, &[cache, "entries"])?, num(stats, &[cache, "capacity"])?);
        ensure!(entries <= cap, "{cache}: {entries} entries over capacity {cap}");
        checked += 1;
    }
    // Persistence: no write failures, no foreign-version entries (this
    // run's own binary wrote everything on disk).
    if let Some(p) = stats.get_opt("persist") {
        ensure!(num(p, &["write_errors"])? == 0, "persist.write_errors must stay zero");
        ensure!(num(p, &["skipped_version"])? == 0, "persist.skipped_version must stay zero");
        checked += 2;
    }
    Ok(checked)
}

/// Record a wave's OK outcomes: the workload set for future warm draws
/// and the fingerprint→workload map for corruption targeting.
fn absorb(rep: &WireWaveReport, warm_ok: &mut BTreeSet<String>, fp_of: &mut BTreeMap<String, String>) {
    for o in &rep.outcomes {
        if o.outcome == "OK" {
            warm_ok.insert(o.workload.clone());
            if let Some(fp) = &o.fingerprint {
                fp_of.insert(fp.clone(), o.workload.clone());
            }
        }
    }
}

/// Saturate the shed-policy `gold` lane (capacity 6) with `n` distinct
/// cold deploys written back to back on one v1 connection: admission
/// control must shed the overflow rather than block or wedge. The
/// burst counter advances monotonically so every burst in a run stays
/// cold, even across warm restarts over the same snapshot dir.
fn gold_burst_fault(addr: &str, burst_counter: &mut usize, n: usize) -> Result<(usize, usize)> {
    let mut c = WireClient::connect(addr)?;
    let base = *burst_counter;
    *burst_counter += n;
    for i in 0..n {
        // seq 260..3859 never collides with the seeded waves (seq ≤
        // 256); hidden bumps when seq wraps so bursts stay distinct.
        let idx = base + i;
        let seq = 4 * (65 + idx % 900);
        let hidden = 32 + 4 * (idx / 900);
        c.send_line(&format!(
            "FTL1 {} DEPLOY stage-{seq}x16x{hidden} cluster-only ftl lane=gold",
            9_000_000 + idx
        ))?;
    }
    let (mut ok, mut shed) = (0usize, 0usize);
    let mut terminals = 0usize;
    while terminals < n {
        let j = c.read_json()?;
        match j.get("event")?.as_str()? {
            "plan" | "sim" => continue,
            "done" => {
                terminals += 1;
                match j.get("outcome")?.as_str()? {
                    "OK" => ok += 1,
                    "SHED" => shed += 1,
                    other => bail!("unexpected burst outcome '{other}': {j}"),
                }
            }
            "error" => bail!("burst request failed: {j}"),
            other => bail!("unexpected burst event '{other}': {j}"),
        }
    }
    ensure!(shed >= 1, "a {n}-deep burst into a capacity-6 shed lane must shed something (served {ok})");
    Ok((ok, shed))
}

/// A client that floods `STATS` and never reads a byte back: the
/// per-connection write queue must overflow and the front door must
/// shed the connection (`frontend.slow_closed`) instead of wedging the
/// event loop or stalling other clients.
fn slow_reader_fault(addr: &str) -> Result<()> {
    use std::io::Write;
    let before = num(&scrape_stats(addr)?, &["frontend", "slow_closed"])?;
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    // ~1500 STATS replies are several MB against the 1 MiB write-queue
    // cap the soak server runs with — the queue must trip no matter
    // what the kernel socket buffers absorb. The server may shed us
    // while the flood is still going out; only an *early* write
    // failure is a harness error.
    for i in 0..1500 {
        if let Err(e) = stream.write_all(b"STATS\n") {
            ensure!(i > 50, "slow-reader flood failed after only {i} writes: {e}");
            break;
        }
    }
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        if num(&scrape_stats(addr)?, &["frontend", "slow_closed"])? > before {
            return Ok(());
        }
        ensure!(Instant::now() < deadline, "slow reader was never shed");
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// One frame past `proto::MAX_FRAME_BYTES`: the front door must answer
/// an `error` event on the frame's own id (`frontend.protocol_errors`)
/// and keep the connection fully usable.
fn oversized_frame_fault(addr: &str) -> Result<()> {
    let mut c = WireClient::connect(addr)?;
    let junk = "x".repeat(crate::serve::proto::MAX_FRAME_BYTES + 1024);
    c.send_line(&format!("FTL1 4242 DEPLOY {junk} cluster-only ftl"))?;
    let j = c.read_json()?;
    ensure!(j.get("event")?.as_str()? == "error", "oversized frame must answer an error event: {j}");
    ensure!(j.get("id")?.as_u64()? == 4242, "the error event must carry the frame's own id: {j}");
    let pong = c.roundtrip("PING")?;
    ensure!(pong.get("pong")?.as_bool()?, "connection must survive an oversized frame: {pong}");
    Ok(())
}

/// Byte-flip the last payload byte of one *plan* entry in the segment
/// files — preferring an entry whose fingerprint is in `warm_fps`, so
/// the re-solve is observable on replay — and drop one garbage JSON
/// envelope beside it. Returns the corrupted fingerprint when it was
/// drawn from `warm_fps` (the loader must skip-and-count both files'
/// damage either way).
fn inject_corruption(dir: &Path, warm_fps: &BTreeSet<String>) -> Result<Option<String>> {
    let paths = segment::segment_paths(dir);
    ensure!(!paths.is_empty(), "no segment files to corrupt in {}", dir.display());
    let mut fallback: Option<(PathBuf, segment::IndexEntry)> = None;
    let mut target: Option<(PathBuf, segment::IndexEntry)> = None;
    'scan: for path in paths.iter().rev() {
        let view = segment::read_segment(path).map_err(|e| anyhow!("reading {}: {e:?}", path.display()))?;
        for ie in &view.entries {
            if ie.kind != 0 {
                continue; // plan entries only (persist::KIND_PLAN)
            }
            if fallback.is_none() {
                fallback = Some((path.clone(), *ie));
            }
            if warm_fps.contains(&ie.key.hex()) {
                target = Some((path.clone(), *ie));
                break 'scan;
            }
        }
    }
    let (path, ie, fp) = match target {
        Some((p, ie)) => {
            let hex = ie.key.hex();
            (p, ie, Some(hex))
        }
        None => {
            let (p, ie) = fallback.ok_or_else(|| anyhow!("no plan entries found in any segment"))?;
            (p, ie, None)
        }
    };
    let mut bytes = std::fs::read(&path)?;
    let at = ie.offset + ie.len - 1;
    ensure!(at < bytes.len(), "index points past the segment file");
    // The per-entry checksum covers every payload byte: one flipped bit
    // must fail exactly this entry, not the file.
    bytes[at] ^= 0x01;
    std::fs::write(&path, &bytes)?;
    // And one well-named envelope with garbage content: the JSON
    // loader must count it corrupt, not crash on it.
    std::fs::write(dir.join("plan-ffffffffffffffffffffffffffffffff.json"), b"{ not json")?;
    Ok(fp)
}

/// Render one wave's outcome record for `BENCH_soak.json`.
fn wave_json(
    wave: usize,
    kind: &str,
    rep: &WireWaveReport,
    wall: Duration,
    faults: &[&str],
    checks: u64,
) -> Json {
    let mut lat: Vec<u64> =
        rep.outcomes.iter().filter(|o| o.outcome == "OK").map(|o| o.latency_us).collect();
    lat.sort_unstable();
    let pct = |p: f64| -> Json {
        if lat.is_empty() {
            return Json::Null;
        }
        let idx = ((lat.len() - 1) as f64 * p).round() as usize;
        Json::int(lat[idx] as usize)
    };
    Json::obj(vec![
        ("wave", Json::int(wave)),
        ("kind", Json::str(kind)),
        ("requests", Json::int(rep.outcomes.len())),
        ("ok", Json::int(rep.count("OK"))),
        ("shed", Json::int(rep.count("SHED"))),
        ("timeout", Json::int(rep.count("TIMEOUT"))),
        ("v0", Json::int(rep.outcomes.iter().filter(|o| o.v0).count())),
        ("plan_events", Json::int(rep.plan_events)),
        ("sim_events", Json::int(rep.sim_events)),
        ("latency_us", Json::obj(vec![("p50", pct(0.50)), ("p90", pct(0.90)), ("max", pct(1.0))])),
        ("wall_ms", Json::Num(wall.as_secs_f64() * 1e3)),
        ("throughput_rps", Json::Num(rep.outcomes.len() as f64 / wall.as_secs_f64().max(1e-9))),
        ("faults", Json::Arr(faults.iter().map(|f| Json::str(*f)).collect())),
        ("invariant_checks", Json::int(checks as usize)),
    ])
}

/// Run the soak: the fixed three-wave skeleton (mixed + burst, warm
/// replay behind a kill, replay through an injected corruption), then
/// seeded churn waves, writing the trajectory report to
/// `opts.out_path`. Returns the report.
pub fn run(opts: &SoakOptions) -> Result<Json> {
    ensure!(opts.waves >= 3, "soak needs at least 3 waves (mixed, warm replay, post-corruption replay)");
    ensure!(opts.requests_per_wave >= 4, "soak waves need at least 4 requests to mix traffic");
    std::fs::create_dir_all(&opts.cache_dir)
        .with_context(|| format!("creating {}", opts.cache_dir.display()))?;
    let run_start = Instant::now();
    let mut rng = Rng::new(opts.seed);
    let mut pool: Vec<String> = Vec::new();
    let mut warm_ok: BTreeSet<String> = BTreeSet::new();
    let mut fp_of: BTreeMap<String, String> = BTreeMap::new();
    let mut waves_out: Vec<Json> = Vec::new();
    let (mut kills, mut corruptions, mut checks) = (0u64, 0u64, 0u64);
    let mut burst_counter = 0usize;
    let burst_depth = if opts.smoke { 16 } else { 24 };

    let mut server = Server::spawn(opts)?;
    println!("[ftl-soak] seed {} · {} waves · server up at {}", opts.seed, opts.waves, server.addr);

    // ---- wave 1: seeded mixed traffic + gold saturation burst ----
    let mix = WireMix { total: opts.requests_per_wave, warm_pct: 40, v0_pct: 25, tight_deadline_pct: 8 };
    let t = Instant::now();
    let checks_before = checks;
    let rep = seeded_wire_wave(&server.addr, &mut rng, &mix, &mut pool)?;
    absorb(&rep, &mut warm_ok, &mut fp_of);
    let (burst_ok, burst_shed) = gold_burst_fault(&server.addr, &mut burst_counter, burst_depth)?;
    let stats = quiesce(&server.addr)?;
    checks += check_invariants(&stats)?;
    ensure!(
        num(&stats, &["batch", "lanes", "gold", "shed"])? >= burst_shed as u64,
        "the burst's sheds must be visible in the gold lane counters"
    );
    checks += 1;
    // Exposition sanity: METRICS flattens the same tree, EOF-framed.
    let mut mc = WireClient::connect(&server.addr)?;
    mc.send_line("METRICS")?;
    let metrics = mc.read_until("# EOF")?;
    ensure!(metrics.len() > 10, "METRICS must expose the counter tree ({} lines)", metrics.len());
    ensure!(metrics.iter().any(|l| l.contains("batch")), "METRICS must carry the batch counters");
    checks += 2;
    println!(
        "[ftl-soak] wave 1 (mixed): {} ok / {} shed / {} timeout; burst served {burst_ok}, shed {burst_shed}",
        rep.count("OK"),
        rep.count("SHED"),
        rep.count("TIMEOUT")
    );
    waves_out.push(wave_json(1, "mixed", &rep, t.elapsed(), &["gold-burst"], checks - checks_before));
    pool = warm_ok.iter().cloned().collect();
    ensure!(!pool.is_empty(), "wave 1 must leave at least one warm workload for the replay waves");

    // ---- kill #1: SIGKILL after the write-behind settles ----
    let settled = settle_persist(&server.addr)?;
    server.kill()?;
    kills += 1;
    server = Server::spawn(opts)?;
    let boot = scrape_stats(&server.addr)?;
    ensure!(
        num(&boot, &["persist", "loaded"])? == settled,
        "warm start must load every entry settled before the SIGKILL ({} vs {settled})",
        num(&boot, &["persist", "loaded"])?
    );
    ensure!(
        num(&boot, &["persist", "skipped_corrupt"])? == 0,
        "atomic segment writes must never leave a torn entry behind a SIGKILL"
    );
    checks += 2;
    println!("[ftl-soak] kill #1 survived: {} entries warm-loaded at {}", settled, server.addr);

    // ---- wave 2: pure warm replay, then client-side faults ----
    let mix = WireMix { total: opts.requests_per_wave, warm_pct: 100, v0_pct: 25, tight_deadline_pct: 0 };
    let t = Instant::now();
    let checks_before = checks;
    let rep = seeded_wire_wave(&server.addr, &mut rng, &mix, &mut pool)?;
    for o in &rep.outcomes {
        ensure!(
            o.outcome == "OK" && o.cached && o.sim_cached,
            "fully-warm replay must hit both caches: {} → {} (cached {}, sim_cached {})",
            o.workload,
            o.outcome,
            o.cached,
            o.sim_cached
        );
    }
    absorb(&rep, &mut warm_ok, &mut fp_of);
    let stats = quiesce(&server.addr)?;
    ensure!(
        num(&stats, &["solves"])? == 0 && num(&stats, &["sims"])? == 0,
        "fully-warm replay must run zero solves and zero sims (got {} / {})",
        num(&stats, &["solves"])?,
        num(&stats, &["sims"])?
    );
    checks += 2;
    slow_reader_fault(&server.addr)?;
    checks += 1;
    oversized_frame_fault(&server.addr)?;
    let stats = scrape_stats(&server.addr)?;
    ensure!(
        num(&stats, &["frontend", "protocol_errors"])? >= 1,
        "the oversized frame must be counted as a protocol error"
    );
    checks += 1;
    checks += check_invariants(&quiesce(&server.addr)?)?;
    println!(
        "[ftl-soak] wave 2 (warm replay): {} ok, zero solver work; slow reader shed, oversized frame bounced",
        rep.count("OK")
    );
    waves_out.push(wave_json(
        2,
        "warm-replay",
        &rep,
        t.elapsed(),
        &["slow-reader", "oversized-frame"],
        checks - checks_before,
    ));

    // ---- kill #2 + corruption injection ----
    let settled = settle_persist(&server.addr)?;
    server.kill()?;
    kills += 1;
    let warm_fps: BTreeSet<String> = fp_of.keys().cloned().collect();
    let corrupted_fp = inject_corruption(&opts.cache_dir, &warm_fps)?;
    corruptions += 1;
    server = Server::spawn(opts)?;
    let boot = scrape_stats(&server.addr)?;
    ensure!(
        num(&boot, &["persist", "skipped_corrupt"])? == 2,
        "the flipped segment entry and the garbage envelope must each be counted (got {})",
        num(&boot, &["persist", "skipped_corrupt"])?
    );
    ensure!(
        num(&boot, &["persist", "loaded"])? == settled - 1,
        "exactly the corrupted entry may be lost ({} loaded vs {} settled)",
        num(&boot, &["persist", "loaded"])?,
        settled
    );
    checks += 2;
    println!(
        "[ftl-soak] kill #2 + corruption survived: 2 skipped_corrupt, {} of {} entries warm",
        settled - 1,
        settled
    );

    // ---- wave 3: warm replay with exactly one hole ----
    let t = Instant::now();
    let checks_before = checks;
    if let Some(fp) = &corrupted_fp {
        let workload = fp_of.get(fp).expect("corruption target was drawn from fp_of");
        let j = WireClient::connect(&server.addr)?
            .roundtrip(&format!("DEPLOY {workload} cluster-only ftl"))?;
        ensure!(
            j.get("outcome")?.as_str()? == "OK" && !j.get("cached")?.as_bool()?,
            "the corrupted plan must re-solve, not crash or serve stale bytes: {j}"
        );
        ensure!(
            j.get("sim_cached")?.as_bool()?,
            "the sim entry was not corrupted and must still hit: {j}"
        );
        checks += 2;
    }
    let mix = WireMix { total: opts.requests_per_wave, warm_pct: 100, v0_pct: 25, tight_deadline_pct: 0 };
    let rep = seeded_wire_wave(&server.addr, &mut rng, &mix, &mut pool)?;
    for o in &rep.outcomes {
        ensure!(o.outcome == "OK", "post-corruption replay must serve everything: {} → {}", o.workload, o.outcome);
    }
    absorb(&rep, &mut warm_ok, &mut fp_of);
    let stats = quiesce(&server.addr)?;
    let solves = num(&stats, &["solves"])?;
    match &corrupted_fp {
        Some(_) => ensure!(solves == 1, "exactly the corrupted plan may re-solve (got {solves})"),
        None => ensure!(solves <= 1, "at most the corrupted plan may re-solve (got {solves})"),
    }
    ensure!(num(&stats, &["sims"])? == 0, "the sim cache must stay fully warm through plan corruption");
    checks += 2;
    checks += check_invariants(&stats)?;
    println!("[ftl-soak] wave 3 (replay through corruption): {} ok, {} re-solve", rep.count("OK"), solves);
    waves_out.push(wave_json(
        3,
        "warm-replay",
        &rep,
        t.elapsed(),
        &["segment-corruption", "json-corruption"],
        checks - checks_before,
    ));

    // ---- waves 4..N: seeded churn — traffic + a fault + coin-flip restarts ----
    for w in 4..=opts.waves {
        let mix =
            WireMix { total: opts.requests_per_wave, warm_pct: 50, v0_pct: 25, tight_deadline_pct: 8 };
        let t = Instant::now();
        let checks_before = checks;
        let rep = seeded_wire_wave(&server.addr, &mut rng, &mix, &mut pool)?;
        absorb(&rep, &mut warm_ok, &mut fp_of);
        let fault = *rng.pick(&["gold-burst", "oversized-frame", "slow-reader"]);
        match fault {
            "gold-burst" => {
                gold_burst_fault(&server.addr, &mut burst_counter, burst_depth)?;
            }
            "oversized-frame" => oversized_frame_fault(&server.addr)?,
            _ => slow_reader_fault(&server.addr)?,
        }
        checks += 1;
        checks += check_invariants(&quiesce(&server.addr)?)?;
        println!(
            "[ftl-soak] wave {w} (mixed churn): {} ok / {} shed / {} timeout; fault {fault}",
            rep.count("OK"),
            rep.count("SHED"),
            rep.count("TIMEOUT")
        );
        waves_out.push(wave_json(w, "mixed", &rep, t.elapsed(), &[fault], checks - checks_before));
        pool = warm_ok.iter().cloned().collect();
        if w < opts.waves && rng.chance(0.5) {
            settle_persist(&server.addr)?;
            server.kill()?;
            kills += 1;
            server = Server::spawn(opts)?;
            let boot = scrape_stats(&server.addr)?;
            // Post-corruption boots keep re-skipping the damaged
            // files; the loader must stay count-stable, never fatal.
            ensure!(num(&boot, &["persist", "loaded"])? >= 1, "churn restart must warm-start");
            ensure!(num(&boot, &["persist", "skipped_version"])? == 0, "no version skips on churn restart");
            checks += 2;
            println!("[ftl-soak] churn restart survived at {}", server.addr);
        }
    }

    let final_stats = quiesce(&server.addr)?;
    checks += check_invariants(&final_stats)?;
    server.kill()?;

    let report = Json::obj(vec![
        ("schema", Json::str("ftl-soak-v1")),
        ("seed", Json::int(opts.seed as usize)),
        ("smoke", Json::Bool(opts.smoke)),
        ("requests_per_wave", Json::int(opts.requests_per_wave)),
        ("kills", Json::int(kills as usize)),
        ("corruptions", Json::int(corruptions as usize)),
        ("invariant_checks", Json::int(checks as usize)),
        ("distinct_workloads", Json::int(warm_ok.len())),
        ("wall_ms", Json::Num(run_start.elapsed().as_secs_f64() * 1e3)),
        ("waves", Json::Arr(waves_out)),
    ]);
    std::fs::write(&opts.out_path, format!("{}\n", report.pretty()))
        .with_context(|| format!("writing {}", opts.out_path.display()))?;
    println!(
        "soak OK: seed={} waves={} kills={kills} corruptions={corruptions} invariant_checks={checks} → {}",
        opts.seed,
        opts.waves,
        opts.out_path.display()
    );
    Ok(report)
}
