//! Property-based tests over the core invariants (in-crate `util::prop`
//! harness — see DESIGN.md; `proptest` is unavailable offline).

use std::collections::HashSet;

use ftl::config::DeployConfig;
use ftl::coordinator::Deployer;
use ftl::ir::builder::{deep_mlp, vit_mlp};
use ftl::ir::{ActKind, DType, GraphBuilder};
use ftl::memory::{AllocRequest, BufferRole, Level, StaticAllocator};
use ftl::runtime::{reference, HostTensor, NativeBackend, TileExecutor};
use ftl::schedule::build_schedule;
use ftl::sim::simulate;
use ftl::tiling::{
    assign_homes, fuse_groups, solve_graph, solve_graph_in, solve_group_exhaustive, solve_group_in, FusionPolicy,
    HomesPolicy, SolverOptions, SolverPool, Strategy,
};
use ftl::util::bincode::{BinReader, BinWriter};
use ftl::util::prop::{cases, Rng};

/// Random small MLP-ish graph.
fn random_graph(rng: &mut Rng) -> ftl::ir::Graph {
    let seq = rng.range(3, 48);
    let d = rng.range(3, 48);
    let mut b = GraphBuilder::new(DType::F32);
    let mut t = b.input("x", &[seq, d]);
    let layers = rng.range(1, 3);
    for i in 0..layers {
        let n = rng.range(3, 64);
        t = b.linear(&format!("fc{i}"), t, n, rng.chance(0.7));
        if rng.chance(0.8) {
            let kind = *rng.pick(&[ActKind::Gelu, ActKind::Relu, ActKind::Sigmoid]);
            t = b.act(&format!("act{i}"), kind, t);
        }
    }
    b.finish(t).expect("random graph is valid")
}

#[test]
fn prop_allocator_no_overlap_and_within_capacity() {
    cases(200, |rng| {
        let n = rng.range(1, 40);
        let reqs: Vec<AllocRequest> = (0..n)
            .map(|i| {
                let birth = rng.range(0, 30);
                AllocRequest::new(i, rng.range(0, 4096), birth, birth + rng.range(0, 10))
            })
            .collect();
        let alloc = StaticAllocator::new(1 << 22, 1 << rng.range(0, 6));
        let placed = alloc.solve(&reqs).expect("capacity is generous");
        alloc.verify(&placed).expect("placement must verify");
    });
}

#[test]
fn prop_allocator_peak_not_worse_than_sum() {
    cases(100, |rng| {
        let n = rng.range(2, 24);
        let reqs: Vec<AllocRequest> = (0..n)
            .map(|i| {
                let birth = rng.range(0, 10);
                AllocRequest::new(i, rng.range(1, 2048), birth, birth + rng.range(0, 6))
            })
            .collect();
        let alloc = StaticAllocator::new(1 << 24, 4);
        let placed = alloc.solve(&reqs).unwrap();
        let peak = StaticAllocator::peak(&placed);
        let aligned_sum: usize = reqs.iter().map(|r| (r.size + 3) & !3).sum();
        assert!(peak <= aligned_sum, "peak {peak} worse than naive sum {aligned_sum}");
    });
}

#[test]
fn prop_solution_fits_l1_and_covers_dims() {
    cases(40, |rng| {
        let graph = random_graph(rng);
        let strategy = if rng.chance(0.5) { Strategy::Ftl } else { Strategy::LayerPerLayer };
        let soc = if rng.chance(0.5) {
            ftl::soc::siracusa_reduced()
        } else {
            ftl::soc::siracusa_reduced_cluster_only()
        };
        let dbuf = rng.chance(0.5);
        let groups = fuse_groups(&graph, strategy, FusionPolicy::default());
        let (_, sol) = solve_graph(&graph, &soc, groups, &SolverOptions::default(), dbuf).expect("solvable");
        for g in &sol.groups {
            assert!(g.footprint <= soc.mem.capacity(Level::L1));
            // loop nest covers each free dim exactly
            for l in &g.loops {
                let covered: usize = {
                    let mut c = 0;
                    let mut off = 0;
                    while off < l.full {
                        c += l.tile.min(l.full - off);
                        off += l.tile;
                    }
                    c
                };
                assert_eq!(covered, l.full);
            }
            // every buffer tile at every iteration stays within bounds
            for state in g.iterations() {
                for b in &g.buffers {
                    let off = b.offsets_at(&state);
                    let shp = b.shape_at(&state);
                    for ((o, s), d) in off.iter().zip(&shp).zip(&b.dims) {
                        assert!(o + s <= d.full.max(o + 1), "tile exceeds dim: {o}+{s} > {}", d.full);
                    }
                }
            }
        }
    });
}

#[test]
fn prop_bnb_solver_matches_exhaustive_oracle() {
    // The parallel branch-and-bound must return the *bit-identical*
    // winner of the naive serial sweep — same (cycles, iters, order,
    // assignment), hence an equal GroupSolution — for any thread count,
    // across random graphs, SoCs and buffering modes. Infeasible groups
    // must fail on both sides.
    cases(15, |rng| {
        let graph = random_graph(rng);
        let strategy = if rng.chance(0.5) { Strategy::Ftl } else { Strategy::LayerPerLayer };
        let soc = if rng.chance(0.5) {
            ftl::soc::siracusa_reduced()
        } else {
            ftl::soc::siracusa_reduced_cluster_only()
        };
        let dbuf = rng.chance(0.5);
        let groups = fuse_groups(&graph, strategy, FusionPolicy::default());
        let homes = assign_homes(&graph, &groups, &soc);
        for gr in &groups {
            let oracle = solve_group_exhaustive(&graph, &soc, gr, &homes, &SolverOptions::default(), dbuf);
            for threads in [1usize, 3] {
                let pool = SolverPool::new(threads);
                let sol = solve_group_in(&graph, &soc, gr, &homes, &SolverOptions::default(), dbuf, &pool);
                match (&oracle, &sol) {
                    (Ok(a), Ok(b)) => assert_eq!(b, a, "B&B diverged from oracle (threads={threads})"),
                    (Err(_), Err(_)) => {}
                    (a, b) => panic!(
                        "feasibility diverged (threads={threads}): oracle={:?} bnb={:?}",
                        a.is_ok(),
                        b.is_ok()
                    ),
                }
            }
        }
    });
}

#[test]
fn prop_search_space_fully_accounted() {
    // Every enumerable point of every solve is scored or pruned exactly
    // once: scored + capacity_pruned + bound_pruned == space, for serial
    // and parallel searches alike.
    cases(10, |rng| {
        let graph = random_graph(rng);
        let strategy = if rng.chance(0.5) { Strategy::Ftl } else { Strategy::LayerPerLayer };
        let pool = SolverPool::new(if rng.chance(0.5) { 1 } else { 4 });
        let soc = ftl::soc::siracusa_reduced();
        let groups = fuse_groups(&graph, strategy, FusionPolicy::default());
        let _ = solve_graph_in(
            &graph,
            &soc,
            groups,
            &SolverOptions::default(),
            rng.chance(0.5),
            HomesPolicy::Resident,
            &pool,
        )
        .expect("random graphs are solvable at the default L1");
        let s = pool.stats();
        assert!(s.solves > 0 && s.space > 0 && s.scored > 0);
        assert_eq!(
            s.scored + s.capacity_pruned + s.bound_pruned,
            s.space,
            "search-space accounting must balance: {s:?}"
        );
    });
}

#[test]
fn prop_tiled_execution_matches_oracle() {
    cases(25, |rng| {
        let graph = random_graph(rng);
        let strategy = if rng.chance(0.5) { Strategy::Ftl } else { Strategy::LayerPerLayer };
        let cfg = DeployConfig::preset(if rng.chance(0.5) { "siracusa" } else { "cluster-only" }, strategy)
            .unwrap();
        let worst = Deployer::new(graph, cfg).validate_numerics(NativeBackend, rng.next_u64()).unwrap();
        assert!(worst < 1e-2, "deviation {worst}");
    });
}

#[test]
fn prop_ftl_dma_bytes_never_exceed_baseline() {
    cases(25, |rng| {
        let graph = random_graph(rng);
        let soc = ftl::soc::siracusa_reduced();
        let run = |strategy| {
            let groups = fuse_groups(&graph, strategy, FusionPolicy::default());
            let (_, sol) = solve_graph(&graph, &soc, groups, &SolverOptions::default(), false).unwrap();
            let sched = build_schedule(&graph, &soc, &sol).unwrap();
            simulate(&sched, &soc).unwrap()
        };
        let base = run(Strategy::LayerPerLayer);
        let ftl_r = run(Strategy::Ftl);
        assert!(
            ftl_r.dma.total_bytes() <= base.dma.total_bytes(),
            "FTL moved more bytes ({} > {})",
            ftl_r.dma.total_bytes(),
            base.dma.total_bytes()
        );
        assert!(ftl_r.total_cycles <= base.total_cycles);
    });
}

#[test]
fn prop_double_buffer_never_hurts() {
    cases(20, |rng| {
        let graph = random_graph(rng);
        let soc = ftl::soc::siracusa_reduced();
        let groups = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
        let run = |dbuf: bool| {
            let (_, sol) =
                solve_graph(&graph, &soc, groups.clone(), &SolverOptions::default(), dbuf).unwrap();
            let sched = build_schedule(&graph, &soc, &sol).unwrap();
            simulate(&sched, &soc).unwrap().total_cycles
        };
        let single = run(false);
        let double = run(true);
        // Double buffering is NOT universally a win — the paper itself
        // notes it only pays when kernel runtime < DMA runtime, and the
        // doubled footprint can force smaller tiles (more per-command
        // setup cycles), which dominates on tiny graphs. The invariant we
        // can assert is a *bounded* regression: the pipeline overlap can
        // never cost more than the extra setup of ~2x the tile count.
        assert!(
            (double as f64) <= single as f64 * 1.25,
            "double buffering cost more than the setup bound: {double} vs {single}"
        );
    });
}

#[test]
fn prop_gather_scatter_roundtrip() {
    cases(100, |rng| {
        let rows = rng.range(1, 40);
        let cols = rng.range(1, 40);
        let src = HostTensor::random(&[rows, cols], rng.next_u64());
        let tr = rng.range(1, rows);
        let tc = rng.range(1, cols);
        let mut dst = HostTensor::zeros(&[rows, cols]);
        let mut r0 = 0;
        while r0 < rows {
            let mut c0 = 0;
            while c0 < cols {
                let tile = src.gather(&[r0, c0], &[tr.min(rows - r0), tc.min(cols - c0)]);
                dst.scatter(&[r0, c0], &tile);
                c0 += tc;
            }
            r0 += tr;
        }
        assert_eq!(src.data, dst.data);
    });
}

#[test]
fn prop_homes_consistent_with_materialisation() {
    cases(40, |rng| {
        let graph = random_graph(rng);
        let soc = ftl::soc::siracusa_reduced();
        let groups = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
        let homes = assign_homes(&graph, &groups, &soc);
        let (groups, sol) = solve_graph(&graph, &soc, groups, &SolverOptions::default(), false).unwrap();
        let homes = {
            // homes may have been recomputed after splits; recompute for
            // the final groups for the invariant check.
            let _ = homes;
            assign_homes(&graph, &groups, &soc)
        };
        let mut intermediate_buffers = HashSet::new();
        for g in &sol.groups {
            for b in &g.buffers {
                if b.role == BufferRole::Intermediate {
                    intermediate_buffers.insert(b.tensor);
                    assert!(b.home.is_none(), "fused intermediate with a home level");
                }
            }
        }
        for t in &intermediate_buffers {
            assert_eq!(homes[*t], None, "home assigned to non-materialised tensor");
        }
        // Every graph input/weight/output must have a home.
        for (i, tensor) in graph.tensors.iter().enumerate() {
            if !matches!(tensor.kind, ftl::ir::TensorKind::Intermediate) {
                assert!(homes[i].is_some(), "{} lacks a home", tensor.name);
            }
        }
    });
}

#[test]
fn prop_reference_ops_shape_agree_with_ir_inference() {
    cases(60, |rng| {
        let graph = random_graph(rng);
        let bindings = reference::random_bindings(&graph, rng.next_u64());
        let env = reference::run_graph(&graph, &bindings).unwrap();
        for node in &graph.nodes {
            assert_eq!(env[&node.output].shape, graph.tensors[node.output].shape);
        }
    });
}

#[test]
fn prop_executor_deterministic() {
    cases(10, |rng| {
        let graph = vit_mlp(rng.range(8, 32), rng.range(8, 32), rng.range(8, 64), DType::F32);
        let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        let dep = Deployer::new(graph, cfg);
        let plan = dep.plan().unwrap();
        let bindings = reference::random_bindings(dep.graph(), 5);
        let mut e1 = TileExecutor::new(NativeBackend);
        let mut e2 = TileExecutor::new(NativeBackend);
        let r1 = e1.run(dep.graph(), &plan.solution, &bindings).unwrap();
        let r2 = e2.run(dep.graph(), &plan.solution, &bindings).unwrap();
        let out = dep.graph().outputs()[0];
        assert_eq!(r1[&out].data, r2[&out].data);
    });
}

#[test]
fn prop_deep_mlp_group_count() {
    cases(20, |rng| {
        let layers = rng.range(1, 5);
        let graph = deep_mlp(16, 32, layers, DType::Int8);
        let groups = fuse_groups(&graph, Strategy::Ftl, FusionPolicy::default());
        // Each Linear+GeLU pair fuses → exactly `layers` groups.
        assert_eq!(groups.len(), layers);
    });
}

#[test]
fn prop_binary_and_json_snapshot_codecs_are_equivalent() {
    // Cross-codec equivalence over random solved plans: the `ftl-bin-v1`
    // binary round-trip and the `ftl-snapshot-v1` JSON round-trip must
    // decode to the same object — and both to the original. A divergence
    // here means a replica warm-started from segments behaves differently
    // from one warm-started from JSON envelopes, which the migration
    // path (`ftl snapshot compact`) must never allow.
    cases(8, |rng| {
        let graph = random_graph(rng);
        let soc = *rng.pick(&["siracusa", "cluster-only"]);
        let strategy = if rng.chance(0.5) { Strategy::Ftl } else { Strategy::LayerPerLayer };
        let mut cfg = DeployConfig::preset(soc, strategy).unwrap();
        cfg.double_buffer = rng.chance(0.5);
        let plan = Deployer::new(graph, cfg.clone()).plan().unwrap();

        let mut w = BinWriter::new();
        plan.to_bin(&mut w);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        let plan_bin = ftl::Deployment::from_bin(&mut r).unwrap();
        assert!(r.is_done(), "binary plan decode must consume every byte");
        let plan_json = ftl::Deployment::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan_bin, plan_json, "binary and JSON plan codecs must decode identically ({soc}, {strategy:?})");
        assert_eq!(plan_bin, plan, "binary plan round-trip must be lossless");

        let sim = plan.simulate(&cfg).unwrap();
        let mut w = BinWriter::new();
        sim.to_bin(&mut w);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        let sim_bin = ftl::sim::SimReport::from_bin(&mut r).unwrap();
        assert!(r.is_done(), "binary sim decode must consume every byte");
        let sim_json = ftl::sim::SimReport::from_json(&sim.to_json()).unwrap();
        assert_eq!(sim_bin, sim_json, "binary and JSON sim codecs must decode identically");
        assert_eq!(sim_bin, sim, "binary sim round-trip must be lossless");

        // The decoded plan is still servable: it re-simulates identically.
        assert_eq!(plan_bin.simulate(&cfg).unwrap(), sim);
    });
}
