//! Edge cases and failure injection across the deployment pipeline.

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::dma::DmaCostModel;
use ftl::ir::{ActKind, DType, Graph, GraphBuilder, Op, Tensor, TensorKind};
use ftl::memory::{Level, LevelSpec};
use ftl::runtime::{reference, NativeBackend, TileExecutor};
use ftl::soc::siracusa_reduced;
use ftl::tiling::{fuse_groups, solve_graph, FusionPolicy, SolverOptions, Strategy};

fn conv_graph(h: usize, w: usize, c: usize, f: usize, pad: usize) -> Graph {
    let mut g = Graph::new();
    let x = g.add_tensor(Tensor::new("x", vec![1, h, w, c], DType::F32, TensorKind::Input)).unwrap();
    let wt = g.add_tensor(Tensor::new("w", vec![3, 3, c, f], DType::F32, TensorKind::Weight)).unwrap();
    g.add_node("conv", Op::Conv2d { kh: 3, kw: 3, stride: 1, pad }, vec![x, wt], "y", TensorKind::Output)
        .unwrap();
    g.validate().unwrap();
    g
}

#[test]
fn conv2d_unpadded_tiles_and_matches_oracle() {
    // Conv with halo'd geometric links (in = out + kh−1): the executor's
    // gather must fetch overlapping input tiles and still match the
    // un-tiled reference.
    let g = conv_graph(20, 22, 8, 16, 0);
    let soc = siracusa_reduced();
    let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy::default());
    let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
    let bindings = reference::random_bindings(&g, 5);
    let oracle = reference::run_graph(&g, &bindings).unwrap();
    let mut exec = TileExecutor::new(NativeBackend);
    let env = exec.run(&g, &sol, &bindings).unwrap();
    let out = g.outputs()[0];
    let diff = env[&out].max_abs_diff(&oracle[&out]);
    assert!(diff < 1e-3, "tiled conv deviates by {diff}");
}

#[test]
fn conv2d_padded_not_spatially_tiled_but_correct() {
    // pad > 0 pins the spatial dims Full (kernel-policy guard); output
    // channels still tile, and numerics must hold.
    let g = conv_graph(12, 12, 4, 32, 1);
    let soc = siracusa_reduced();
    let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy::default());
    let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
    // spatial loops must not appear (ho, wo fixed) — free loops cover N, F only.
    for gr in &sol.groups {
        for l in &gr.loops {
            assert!(l.full == 1 || l.full == 32, "unexpected free loop over extent {}", l.full);
        }
    }
    let bindings = reference::random_bindings(&g, 6);
    let oracle = reference::run_graph(&g, &bindings).unwrap();
    let mut exec = TileExecutor::new(NativeBackend);
    let env = exec.run(&g, &sol, &bindings).unwrap();
    let out = g.outputs()[0];
    assert!(env[&out].max_abs_diff(&oracle[&out]) < 1e-3);
}

#[test]
fn conv_then_relu_fuses() {
    let mut g = conv_graph(16, 16, 8, 16, 0);
    // append relu consuming y
    let (y, _) = g.tensor_by_name("y").unwrap();
    g.tensors[y].kind = TensorKind::Intermediate;
    let out = g.add_tensor(Tensor::new("z", vec![1, 14, 14, 16], DType::F32, TensorKind::Output)).unwrap();
    g.nodes.push(ftl::ir::Node { name: "relu".into(), op: Op::Act(ActKind::Relu), inputs: vec![y], output: out });
    g.validate().unwrap();
    let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy::default());
    assert_eq!(groups.len(), 1, "conv+relu should fuse");
    let soc = siracusa_reduced();
    let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
    let bindings = reference::random_bindings(&g, 7);
    let oracle = reference::run_graph(&g, &bindings).unwrap();
    let mut exec = TileExecutor::new(NativeBackend);
    let env = exec.run(&g, &sol, &bindings).unwrap();
    assert!(env[&out].max_abs_diff(&oracle[&out]) < 1e-3);
}

#[test]
fn tiny_l1_single_node_is_an_error() {
    let g = experiments::vit_mlp_stage(197, 768, 3072);
    let mut soc = siracusa_reduced();
    // L1 too small for even one minimal GEMM tile (needs a full-K row).
    soc.mem.l1 = LevelSpec::new(1024, 4);
    let groups = fuse_groups(&g, Strategy::LayerPerLayer, FusionPolicy::default());
    let err = solve_graph(&g, &soc, groups, &SolverOptions::default(), false);
    assert!(err.is_err(), "1 KiB L1 must be infeasible for a K=768 GEMM");
}

#[test]
fn small_l1_forces_fusion_fallback() {
    // L1 big enough for single layers at small tiles but too small for
    // the fused group -> FTL falls back to per-layer groups and still works.
    let g = experiments::vit_mlp_stage(64, 128, 256);
    let mut soc = siracusa_reduced();
    soc.mem.l1 = LevelSpec::new(3 * 1024, 4);
    let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy::default());
    match solve_graph(&g, &soc, groups, &SolverOptions::default(), false) {
        Ok((final_groups, sol)) => {
            assert_eq!(final_groups.iter().map(|gr| gr.len()).sum::<usize>(), 2);
            assert!(sol.peak_l1() <= 3 * 1024);
        }
        Err(_) => {
            // Also acceptable: genuinely infeasible at this L1. But the
            // per-layer baseline must then fail identically, not worse.
            let base = fuse_groups(&g, Strategy::LayerPerLayer, FusionPolicy::default());
            assert!(solve_graph(&g, &soc, base, &SolverOptions::default(), false).is_err());
        }
    }
}

#[test]
fn seq_one_token_works() {
    let g = experiments::vit_mlp_stage(1, 64, 256);
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    let dep = Deployer::new(g, cfg);
    let (_, report) = dep.deploy().unwrap();
    assert!(report.sim.total_cycles > 0);
    assert!(dep.validate_numerics(NativeBackend, 1).unwrap() < 1e-3);
}

#[test]
fn degenerate_1x1x1_graph() {
    let mut b = GraphBuilder::new(DType::F32);
    let x = b.input("x", &[1, 1]);
    let fc = b.linear("fc", x, 1, true);
    let act = b.act("a", ActKind::Gelu, fc);
    let g = b.finish(act).unwrap();
    let cfg = DeployConfig::preset("cluster-only", Strategy::Ftl).unwrap();
    let dep = Deployer::new(g, cfg);
    assert!(dep.validate_numerics(NativeBackend, 2).unwrap() < 1e-5);
}

#[test]
fn zero_bandwidth_config_rejected() {
    let mut cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    cfg.soc.dma_io = DmaCostModel { setup_cycles: 1, per_row_cycles: 0, bytes_per_cycle: 0.0 };
    assert!(cfg.validate().is_err());
}

#[test]
fn requant_chain_fuses_and_is_identity_in_f32() {
    // int8 deployments insert Requant after GEMM; in the f32 numerics
    // path it is the identity, and it must fuse like any elementwise op.
    let mut g = Graph::new();
    let x = g.add_tensor(Tensor::new("x", vec![16, 32], DType::F32, TensorKind::Input)).unwrap();
    let w = g.add_tensor(Tensor::new("w", vec![32, 24], DType::F32, TensorKind::Weight)).unwrap();
    let (_, acc) = g
        .add_node("mm", Op::Gemm { transpose_b: false, has_bias: false }, vec![x, w], "acc", TensorKind::Intermediate)
        .unwrap();
    let (_, rq) = g.add_node("rq", Op::Requant, vec![acc], "q", TensorKind::Intermediate).unwrap();
    g.add_node("act", Op::Act(ActKind::Relu), vec![rq], "y", TensorKind::Output).unwrap();
    g.validate().unwrap();
    let groups = fuse_groups(&g, Strategy::Ftl, FusionPolicy::default());
    assert_eq!(groups.len(), 1, "gemm+requant+relu should be one group");
    let soc = siracusa_reduced();
    let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
    let bindings = reference::random_bindings(&g, 9);
    let oracle = reference::run_graph(&g, &bindings).unwrap();
    let mut exec = TileExecutor::new(NativeBackend);
    let env = exec.run(&g, &sol, &bindings).unwrap();
    let out = g.outputs()[0];
    assert_eq!(env[&out].data, oracle[&out].data, "requant path must be exact in f32");
}

#[test]
fn transpose_layer_deploys() {
    let mut g = Graph::new();
    let x = g.add_tensor(Tensor::new("x", vec![48, 64], DType::F32, TensorKind::Input)).unwrap();
    g.add_node("t", Op::Transpose, vec![x], "y", TensorKind::Output).unwrap();
    let soc = siracusa_reduced();
    let groups = fuse_groups(&g, Strategy::LayerPerLayer, FusionPolicy::default());
    let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
    let bindings = reference::random_bindings(&g, 10);
    let oracle = reference::run_graph(&g, &bindings).unwrap();
    let mut exec = TileExecutor::new(NativeBackend);
    let env = exec.run(&g, &sol, &bindings).unwrap();
    let out = g.outputs()[0];
    assert!(env[&out].max_abs_diff(&oracle[&out]) < 1e-6);
}

#[test]
fn softmax_rows_not_tiled_along_last_dim() {
    let mut g = Graph::new();
    let x = g.add_tensor(Tensor::new("x", vec![197, 197], DType::F32, TensorKind::Input)).unwrap();
    g.add_node("sm", Op::Softmax, vec![x], "y", TensorKind::Output).unwrap();
    let soc = siracusa_reduced();
    let groups = fuse_groups(&g, Strategy::LayerPerLayer, FusionPolicy::default());
    let (_, sol) = solve_graph(&g, &soc, groups, &SolverOptions::default(), false).unwrap();
    // The last dim is Full per kernel policy -> only the row loop is free.
    assert_eq!(sol.groups[0].loops.len(), 1);
    let bindings = reference::random_bindings(&g, 11);
    let oracle = reference::run_graph(&g, &bindings).unwrap();
    let mut exec = TileExecutor::new(NativeBackend);
    let env = exec.run(&g, &sol, &bindings).unwrap();
    let out = g.outputs()[0];
    assert!(env[&out].max_abs_diff(&oracle[&out]) < 1e-5);
}

#[test]
fn attention_head_deploys_and_matches_oracle() {
    // transpose_b GEMM (Q·Kᵀ) + Softmax row policy inside one deployment;
    // softmax fusing onto `scores` must not break numerics either way.
    use ftl::ir::builder::attention_head;
    for (strategy, npu) in
        [(Strategy::Ftl, true), (Strategy::Ftl, false), (Strategy::LayerPerLayer, true)]
    {
        let g = attention_head(48, 64, 16, DType::F32);
        let cfg = DeployConfig::preset(if npu { "siracusa" } else { "cluster-only" }, strategy).unwrap();
        let dep = Deployer::new(g, cfg);
        let (_, report) = dep.deploy().unwrap();
        assert!(report.sim.total_cycles > 0);
        let worst = dep.validate_numerics(NativeBackend, 21).unwrap();
        assert!(worst < 1e-4, "attention numerics off by {worst} ({strategy:?}, npu={npu})");
    }
}

#[test]
fn attention_head_paper_scale_simulates() {
    use ftl::ir::builder::attention_head;
    let g = attention_head(197, 768, 64, DType::Int8);
    let base = Deployer::new(g.clone(), DeployConfig::preset("siracusa", Strategy::LayerPerLayer).unwrap())
        .deploy()
        .unwrap()
        .1;
    let ftl_r =
        Deployer::new(g, DeployConfig::preset("siracusa", Strategy::Ftl).unwrap()).deploy().unwrap().1;
    assert!(ftl_r.sim.total_cycles <= base.sim.total_cycles);
    assert!(ftl_r.sim.dma.total_bytes() <= base.sim.dma.total_bytes());
}

#[test]
fn lifetime_policy_keeps_stage_mechanism() {
    // The paper's overflow survives the smarter allocator on the stage:
    // the intermediate's live range overlaps the resident weights.
    use ftl::tiling::{assign_homes_with, HomesPolicy};
    let g = experiments::vit_mlp_stage(197, 768, 3072);
    let soc = siracusa_reduced();
    let groups = fuse_groups(&g, Strategy::LayerPerLayer, FusionPolicy::default());
    for policy in [HomesPolicy::Resident, HomesPolicy::Lifetime] {
        let homes = assign_homes_with(&g, &groups, &soc, policy);
        let (h, _) = g.tensor_by_name("fc1_1").unwrap();
        assert_eq!(homes[h], Some(Level::L3), "{policy:?}: intermediate must spill");
    }
}

#[test]
fn lifetime_policy_recovers_deep_mlp_activations() {
    // Divergence case: resident packing spills some activations of a deep
    // MLP; lifetime packing keeps them all in L2 (only ~2 live at once).
    use ftl::ir::builder::deep_mlp;
    use ftl::tiling::{assign_homes_with, HomesPolicy};
    let g = deep_mlp(512, 768, 4, DType::Int8);
    let soc = ftl::soc::siracusa_reduced_cluster_only();
    let groups = fuse_groups(&g, Strategy::LayerPerLayer, FusionPolicy::default());
    let count_l3 = |policy| {
        assign_homes_with(&g, &groups, &soc, policy)
            .iter()
            .filter(|h| **h == Some(Level::L3))
            .count()
    };
    assert!(count_l3(HomesPolicy::Lifetime) < count_l3(HomesPolicy::Resident));
}

#[test]
fn lifetime_policy_numerics_hold() {
    use ftl::tiling::HomesPolicy;
    let g = experiments::vit_mlp_stage(48, 64, 160);
    let mut cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    cfg.homes = HomesPolicy::Lifetime;
    let worst = Deployer::new(g, cfg).validate_numerics(NativeBackend, 13).unwrap();
    assert!(worst < 1e-3);
}

#[test]
fn l3_slower_configs_increase_ftl_benefit() {
    // Monotonicity of the mechanism: slowing L3 widens the baseline/FTL
    // gap (more expensive intermediate round trip).
    let run = |l3_bpc: f64| {
        let g = experiments::vit_mlp_stage(197, 768, 3072);
        let mut cfg = DeployConfig::preset("cluster-only", Strategy::LayerPerLayer).unwrap();
        cfg.soc.dma_io.bytes_per_cycle = l3_bpc;
        let base = Deployer::new(g.clone(), cfg.clone()).deploy().unwrap().1.sim.total_cycles;
        cfg.strategy = Strategy::Ftl;
        let ftl_c = Deployer::new(g, cfg).deploy().unwrap().1.sim.total_cycles;
        100.0 * (base as f64 - ftl_c as f64) / base as f64
    };
    let fast = run(0.4);
    let slow = run(0.05);
    assert!(slow > fast, "slower L3 must increase FTL's win ({slow:.1}% vs {fast:.1}%)");
}
