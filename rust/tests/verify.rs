//! Integration tests for the static plan verifier (`ftl::verify`).
//!
//! Three trust boundaries are exercised end to end:
//!
//! * every builtin serve workload × SoC preset × strategy × buffering mode
//!   plans to a deployment the verifier passes with **zero** findings;
//! * randomly generated graphs (the PR-4 property generator) verify clean
//!   regardless of solver thread count, and the `Finding` JSON codec
//!   round-trips through its own text form;
//! * a hand-corrupted snapshot entry whose envelope checksum is *valid*
//!   (only the payload semantics are wrong) is refused at warm-start by
//!   the verification gate — the integrity check alone cannot catch it.

#![forbid(unsafe_code)]

use std::sync::Arc;

use ftl::config::DeployConfig;
use ftl::coordinator::{Deployer, Deployment};
use ftl::ir::{ActKind, DType, Graph, GraphBuilder};
use ftl::schedule::build_schedule;
use ftl::serve::{
    checksum, resolve_workload, PersistOptions, PlanService, ServeOptions, Snapshotter, SNAPSHOT_FORMAT,
};
use ftl::soc::SocConfig;
use ftl::tiling::{
    assign_homes_with, fuse_groups, solve_graph_in, FusionPolicy, HomesPolicy, SolverOptions, SolverPool, Strategy,
};
use ftl::util::json::{parse, Json};
use ftl::util::prop::{cases, Rng};
use ftl::verify::{check_deployment, Finding, Rule, Severity};

/// The serve-vocabulary workloads the CLI `verify --all` sweep also uses.
const WORKLOADS: [&str; 3] = ["vit-base-stage", "vit-tiny-stage", "stage-64x96x192"];

#[test]
fn builtin_serve_workloads_verify_clean() {
    for name in WORKLOADS {
        let graph = resolve_workload(name).expect("builtin workload resolves");
        for soc in ["siracusa", "cluster-only"] {
            for strategy in [Strategy::Ftl, Strategy::LayerPerLayer] {
                for dbuf in [false, true] {
                    let mut cfg = DeployConfig::preset(soc, strategy).expect("builtin preset");
                    cfg.double_buffer = dbuf;
                    let dep = Deployer::new(graph.clone(), cfg.clone()).plan().expect("workload plans");
                    let report = check_deployment(&dep, Some(&cfg.soc));
                    assert!(
                        report.findings.is_empty(),
                        "{name} on {soc} ({strategy:?}, dbuf={dbuf}) flagged:\n{}",
                        report.render()
                    );
                }
            }
        }
    }
}

/// Random small MLP-ish graph (same shape as the PR-4 property suite).
fn random_graph(rng: &mut Rng) -> ftl::ir::Graph {
    let seq = rng.range(3, 48);
    let d = rng.range(3, 48);
    let mut b = GraphBuilder::new(DType::F32);
    let mut t = b.input("x", &[seq, d]);
    let layers = rng.range(1, 3);
    for i in 0..layers {
        let n = rng.range(3, 64);
        t = b.linear(&format!("fc{i}"), t, n, rng.chance(0.7));
        if rng.chance(0.8) {
            let kind = *rng.pick(&[ActKind::Gelu, ActKind::Relu, ActKind::Sigmoid]);
            t = b.act(&format!("act{i}"), kind, t);
        }
    }
    b.finish(t).expect("random graph is valid")
}

/// Assemble a deployment from the raw pipeline (fuse → solve → homes →
/// schedule) on an explicit, private solver pool.
fn plan_with_pool(graph: &Graph, soc: &SocConfig, strategy: Strategy, dbuf: bool, threads: usize) -> Deployment {
    let pool = SolverPool::new(threads);
    let opts = SolverOptions::default();
    let groups = fuse_groups(graph, strategy, FusionPolicy::default());
    let (groups, solution) =
        solve_graph_in(graph, soc, groups, &opts, dbuf, HomesPolicy::Resident, &pool).expect("random graph solves");
    let homes = assign_homes_with(graph, &groups, soc, HomesPolicy::Resident);
    let schedule = build_schedule(graph, soc, &solution).expect("schedule builds");
    Deployment { groups, homes, solution, schedule }
}

/// Plans must verify clean no matter how many solver threads produced
/// them: the solver is deterministic across thread counts, and the
/// verifier judges only the artifact.
#[test]
fn prop_random_plans_verify_clean_at_any_thread_count() {
    cases(10, |rng| {
        let graph = random_graph(rng);
        let strategy = if rng.chance(0.5) { Strategy::Ftl } else { Strategy::LayerPerLayer };
        let soc = if rng.chance(0.5) {
            ftl::soc::siracusa_reduced()
        } else {
            ftl::soc::siracusa_reduced_cluster_only()
        };
        let dbuf = rng.chance(0.5);
        for threads in [1, 3] {
            let dep = plan_with_pool(&graph, &soc, strategy, dbuf, threads);
            let report = check_deployment(&dep, Some(&soc));
            assert!(
                report.findings.is_empty(),
                "random plan ({strategy:?}, dbuf={dbuf}, threads={threads}) flagged:\n{}",
                report.render()
            );
        }
    });
}

#[test]
fn finding_json_round_trips_through_text() {
    let samples = [
        Finding {
            rule: Rule::DmaRace,
            severity: Severity::Error,
            phase: Some(3),
            detail: "step 7 prefetch of 'x' [0x100, 0x180) overlaps kernel span".into(),
        },
        Finding { rule: Rule::TripCount, severity: Severity::Warning, phase: None, detail: "nest too large".into() },
    ];
    for finding in samples {
        let text = finding.to_json().to_string();
        let back = Finding::from_json(&parse(&text).expect("finding text parses")).expect("finding decodes");
        assert_eq!(back, finding);
    }
    // Every rule name must survive the name round-trip — the JSON codec
    // depends on it.
    for rule in Rule::ALL {
        assert_eq!(Rule::parse(rule.name()), Some(rule));
    }
}

/// A snapshot entry that decodes cleanly and carries a *valid* checksum,
/// but whose payload violates an arena invariant, must be refused by the
/// verification gate at warm-start — and served traffic must simply
/// re-solve.
#[test]
fn corrupted_snapshot_entry_is_rejected_at_warm_start() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("ftl-verify-snap-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let graph = resolve_workload("vit-tiny-stage")?;
    let cfg = DeployConfig::preset("cluster-only", Strategy::Ftl)?;

    // 1. Populate the snapshot directory with one valid plan entry.
    let service = Arc::new(PlanService::new(ServeOptions { workers: 1, ..ServeOptions::default() }));
    let snap = Snapshotter::attach(service.clone(), dir.clone(), PersistOptions::manual())?;
    let cold = service.plan(&graph, &cfg)?;
    assert!(!cold.cached);
    assert!(snap.flush() >= 1, "the fresh plan must be persisted");
    drop(snap);
    drop(service);

    // 2. Hand-corrupt the entry: collide two sized arena offsets, then
    //    recompute the envelope checksum so the persistence layer's own
    //    integrity check still passes. Only the verifier can catch this.
    let key = cold.fingerprint;
    let path = dir.join(format!("plan-{}.json", key.hex()));
    let doc = parse(&std::fs::read_to_string(&path)?)?;
    let mut plan = Deployment::from_json(doc.get("payload")?)?;
    let phase = &mut plan.schedule.phases[0];
    let sized: Vec<usize> = (0..phase.arena.buffers.len())
        .filter(|&i| phase.arena.buffers[i].bytes > 0 && !phase.arena.offsets[i].is_empty())
        .collect();
    assert!(sized.len() >= 2, "need two sized buffers to collide");
    phase.arena.offsets[sized[1]][0] = phase.arena.offsets[sized[0]][0];
    let payload = plan.to_json();
    let payload_text = payload.to_string();
    let sum = checksum(format!("plan\n{}\n{payload_text}", key.hex()).as_bytes());
    let envelope = Json::obj(vec![
        ("format", Json::str(SNAPSHOT_FORMAT)),
        ("kind", Json::str("plan")),
        ("fingerprint", Json::str(key.hex())),
        ("checksum", Json::str(sum.hex())),
        ("payload", payload),
    ]);
    std::fs::write(&path, envelope.to_string())?;

    // 3. Warm-start with verification on: the entry must be rejected by
    //    the gate (verify.rejected), not miscounted as corrupt — its
    //    checksum is genuinely valid.
    let service =
        Arc::new(PlanService::new(ServeOptions { workers: 1, verify_plans: true, ..ServeOptions::default() }));
    let snap = Snapshotter::attach(service.clone(), dir.clone(), PersistOptions::manual())?;
    assert_eq!(snap.counters().skipped_corrupt(), 0, "checksum-valid entry must not count as corrupt");
    assert_eq!(snap.counters().loaded(), 0, "rejected entry must not count as loaded");
    let v = service.stats_json().get("verify")?.clone();
    assert_eq!(v.get("checked")?.as_usize()?, 1);
    assert_eq!(v.get("rejected")?.as_usize()?, 1);
    assert!(v.get("findings")?.as_usize()? >= 1);

    // 4. Served traffic is unaffected: the same request misses the cache,
    //    re-solves cleanly, and passes the insertion-time gate.
    let reply = service.plan(&graph, &cfg)?;
    assert!(!reply.cached, "rejected snapshot must not warm the cache");
    assert_eq!(service.stats().solves, 1);
    let v = service.stats_json().get("verify")?.clone();
    assert_eq!(v.get("checked")?.as_usize()?, 2, "the fresh solve is checked once at insertion");
    assert_eq!(v.get("rejected")?.as_usize()?, 1);

    drop(snap);
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
