//! Integration tests for the `ftl::serve` layer: fingerprint contract,
//! LRU eviction, single-flight coalescing under real concurrency, plan
//! sharing, the batching scheduler (admission control, deadlines,
//! fan-out), the sim-report cache, and the `ftl serve --self-test` CLI
//! path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use ftl::config::DeployConfig;
use ftl::coordinator::experiments;
use ftl::serve::{
    fingerprint, AdmissionPolicy, BatchOptions, BatchOutcome, BatchScheduler, Fingerprint, LruCache,
    PlanService, ServeOptions, SingleFlight,
};
use ftl::tiling::Strategy;
use ftl::Graph;

fn small_graph() -> Graph {
    experiments::vit_mlp_stage(16, 24, 48)
}

fn cfg(soc: &str, strategy: Strategy) -> DeployConfig {
    DeployConfig::preset(soc, strategy).unwrap()
}

fn opts(cache_capacity: usize, cache_shards: usize, workers: usize) -> ServeOptions {
    ServeOptions { cache_capacity, cache_shards, workers, ..ServeOptions::default() }
}

// ---------------------------------------------------------------- fingerprint

#[test]
fn fingerprint_stable_across_rebuilds_and_runs_of_the_encoder() {
    let c = cfg("siracusa", Strategy::Ftl);
    let a = fingerprint(&small_graph(), &c);
    let b = fingerprint(&small_graph(), &c);
    assert_eq!(a, b, "structurally identical requests must share a key");
}

#[test]
fn fingerprint_ignores_names_but_not_structure() {
    let c = cfg("siracusa", Strategy::Ftl);
    let g = small_graph();
    let base = fingerprint(&g, &c);

    // Renaming every tensor/node is cosmetic: same key.
    let mut renamed = g.clone();
    for t in &mut renamed.tensors {
        t.name.push_str("_x");
    }
    for n in &mut renamed.nodes {
        n.name.push_str("_x");
    }
    assert_eq!(base, fingerprint(&renamed, &c));

    // Any shape change is structural: new key.
    assert_ne!(base, fingerprint(&experiments::vit_mlp_stage(16, 24, 64), &c));
    assert_ne!(base, fingerprint(&experiments::vit_mlp_stage(17, 24, 48), &c));
}

#[test]
fn fingerprint_discriminates_every_config_knob() {
    let g = small_graph();
    let base = fingerprint(&g, &cfg("siracusa", Strategy::Ftl));
    let mut keys = vec![base];

    keys.push(fingerprint(&g, &cfg("siracusa", Strategy::LayerPerLayer)));
    keys.push(fingerprint(&g, &cfg("cluster-only", Strategy::Ftl)));

    let mut dbuf = cfg("siracusa", Strategy::Ftl);
    dbuf.double_buffer = true;
    keys.push(fingerprint(&g, &dbuf));

    let mut perf = cfg("siracusa", Strategy::Ftl);
    perf.solver.use_perf_constraints = false;
    keys.push(fingerprint(&g, &perf));

    let mut budget = cfg("siracusa", Strategy::Ftl);
    budget.solver.l1_budget_fraction = 0.5;
    keys.push(fingerprint(&g, &budget));

    let mut homes = cfg("siracusa", Strategy::Ftl);
    homes.homes = ftl::tiling::HomesPolicy::Lifetime;
    keys.push(fingerprint(&g, &homes));

    let distinct: std::collections::BTreeSet<u128> = keys.iter().map(|k| k.0).collect();
    assert_eq!(distinct.len(), keys.len(), "every planning knob must produce a distinct key");
}

// ----------------------------------------------------------------------- LRU

#[test]
fn lru_evicts_in_recency_order() {
    let cache: LruCache<&'static str> = LruCache::new(2, 1);
    cache.insert(Fingerprint(1), "one");
    cache.insert(Fingerprint(2), "two");
    assert_eq!(cache.get(Fingerprint(1)), Some("one")); // 1 newer than 2
    cache.insert(Fingerprint(3), "three");
    assert!(cache.contains(Fingerprint(1)));
    assert!(!cache.contains(Fingerprint(2)), "least-recently-used entry must go first");
    assert!(cache.contains(Fingerprint(3)));
    let stats = cache.stats();
    assert_eq!(stats.evictions, 1);
    assert_eq!(stats.entries, 2);
}

#[test]
fn service_eviction_forces_resolve() {
    // Capacity 1: alternating keys always evict each other.
    let svc = PlanService::new(opts(1, 1, 1));
    let g = small_graph();
    let a = cfg("cluster-only", Strategy::Ftl);
    let b = cfg("cluster-only", Strategy::LayerPerLayer);
    svc.plan(&g, &a).unwrap();
    svc.plan(&g, &b).unwrap(); // evicts a
    svc.plan(&g, &a).unwrap(); // must re-solve
    let stats = svc.stats();
    assert_eq!(stats.solves, 3);
    assert!(stats.cache.evictions >= 2);
}

// -------------------------------------------------------------- single-flight

#[test]
fn n_concurrent_identical_requests_one_solve() {
    let svc = PlanService::new(opts(16, 4, 1));
    let g = small_graph();
    let c = cfg("cluster-only", Strategy::Ftl);
    const N: usize = 8;
    let cycles: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|_| s.spawn(|| svc.deploy("t", &g, &c).unwrap().report.sim.total_cycles))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "all coalesced replies must agree");
    let stats = svc.stats();
    assert_eq!(stats.solves, 1, "N concurrent identical requests must perform exactly 1 solve");
    assert_eq!(stats.sims, 1, "N concurrent identical requests must perform exactly 1 simulation");
    assert_eq!(stats.requests, N as u64);
}

#[test]
fn singleflight_counts_leader_and_followers() {
    let sf: SingleFlight<usize> = SingleFlight::new();
    let runs = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..6 {
            s.spawn(|| {
                let (res, _) = sf.run(9, || {
                    runs.fetch_add(1, Ordering::SeqCst);
                    let start = std::time::Instant::now();
                    while sf.waits() < 5 && start.elapsed() < std::time::Duration::from_secs(10) {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Ok(7)
                });
                assert_eq!(res.unwrap(), 7);
            });
        }
    });
    assert_eq!(runs.load(Ordering::SeqCst), 1);
    assert_eq!(sf.leads(), 1);
    assert_eq!(sf.waits(), 5);
}

// ------------------------------------------------------------- plan sharing

#[test]
fn served_plans_are_shared_not_copied() {
    let svc = PlanService::with_defaults();
    let g = small_graph();
    let c = cfg("cluster-only", Strategy::Ftl);
    let first = svc.plan(&g, &c).unwrap();
    let second = svc.plan(&g, &c).unwrap();
    assert!(Arc::ptr_eq(&first.plan, &second.plan), "warm hits must share one Arc<Deployment>");
    assert!(!first.cached && second.cached);
    // The shared plan still produces per-request reports.
    let report = first.plan.report("relabelled", &c).unwrap();
    assert_eq!(report.workload, "relabelled");
    assert!(report.sim.total_cycles > 0);
}

#[test]
fn cached_plan_report_matches_direct_pipeline() {
    let svc = PlanService::with_defaults();
    let g = small_graph();
    let c = cfg("siracusa", Strategy::Ftl);
    let via_cache = svc.deploy("w", &g, &c).unwrap();
    let (_, direct) = ftl::Deployer::new(g.clone(), c.clone()).with_workload_name("w").deploy().unwrap();
    assert_eq!(via_cache.report.sim.total_cycles, direct.sim.total_cycles);
    assert_eq!(via_cache.report.dma_bytes, direct.dma_bytes);
    assert_eq!(via_cache.report.peak_l1, direct.peak_l1);
}

// ----------------------------------------------------------- sim-report cache

#[test]
fn sim_reports_cached_by_plan_fingerprint() {
    let svc = PlanService::with_defaults();
    let g = small_graph();
    let c = cfg("cluster-only", Strategy::Ftl);
    let cold = svc.deploy("first", &g, &c).unwrap();
    assert!(!cold.sim_cached, "first deploy must run the engine");
    let warm = svc.deploy("second", &g, &c).unwrap();
    assert!(warm.sim_cached, "repeat deploy must hit the sim cache");
    assert_eq!(warm.report.sim.total_cycles, cold.report.sim.total_cycles);
    assert_eq!(warm.report.workload, "second", "cached sim must not leak the first workload label");
    let stats = svc.stats();
    assert_eq!(stats.sims, 1);
    assert_eq!(stats.sim_cache.hits, 1);
    assert_eq!(stats.sim_cache.misses, 1);
    assert!(stats.sim_cache.hit_rate() > 0.49);
}

// ----------------------------------------------------------- batch scheduler

fn batch_opts(queue_capacity: usize, window_ms: u64, policy: AdmissionPolicy) -> BatchOptions {
    BatchOptions {
        queue_capacity,
        batch_window: Duration::from_millis(window_ms),
        policy,
        ..BatchOptions::default()
    }
}

#[test]
fn zero_capacity_queue_sheds_under_both_policies() {
    for policy in [AdmissionPolicy::Shed, AdmissionPolicy::Block] {
        let sched = BatchScheduler::new(
            Arc::new(PlanService::new(opts(4, 1, 1))),
            batch_opts(0, 0, policy),
        );
        let outcome = sched.deploy("z", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
        assert!(matches!(outcome, BatchOutcome::Shed), "zero-capacity must shed under {policy:?}");
        assert_eq!(sched.stats().shed, 1);
        assert_eq!(sched.service().stats().solves, 0);
    }
}

#[test]
fn deadline_expired_at_enqueue_times_out_without_solving() {
    let sched = BatchScheduler::new(Arc::new(PlanService::new(opts(4, 1, 1))), batch_opts(8, 0, AdmissionPolicy::Shed));
    let outcome = sched
        .deploy_with_deadline("late", small_graph(), cfg("cluster-only", Strategy::Ftl), Some(Duration::ZERO))
        .unwrap();
    assert!(matches!(outcome, BatchOutcome::TimedOut));
    let stats = sched.stats();
    assert_eq!(stats.timeouts, 1);
    assert_eq!(stats.batched_requests, 0, "a pre-expired request must never enter the queue");
    assert_eq!(sched.service().stats().requests, 0);
}

#[test]
fn full_queue_sheds_with_shed_policy() {
    // Capacity 1 + a long batch window: the first request sits in the
    // queue for the whole window, so the second arrives at a full queue.
    let sched = Arc::new(BatchScheduler::new(
        Arc::new(PlanService::new(opts(4, 1, 1))),
        batch_opts(1, 1_000, AdmissionPolicy::Shed),
    ));
    let occupant = {
        let sched = sched.clone();
        std::thread::spawn(move || sched.deploy("occupant", small_graph(), cfg("cluster-only", Strategy::Ftl)))
    };
    // Wait until the occupant actually occupies the queue (or is being
    // collected — either way depth+batched covers it).
    let start = std::time::Instant::now();
    while sched.stats().queue_depth == 0
        && sched.stats().batched_requests == 0
        && start.elapsed() < Duration::from_secs(10)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    let outcome = sched.deploy("overflow", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    assert!(matches!(outcome, BatchOutcome::Shed), "full queue must shed instead of blocking");
    assert_eq!(sched.stats().shed, 1);
    let first = occupant.join().unwrap().unwrap();
    assert!(matches!(first, BatchOutcome::Served(_)), "the occupant must still be served");
}

#[test]
fn full_queue_blocks_then_serves_with_block_policy() {
    let sched = Arc::new(BatchScheduler::new(
        Arc::new(PlanService::new(opts(4, 1, 1))),
        batch_opts(1, 50, AdmissionPolicy::Block),
    ));
    let mut handles = Vec::new();
    for i in 0..4 {
        let sched = sched.clone();
        handles.push(std::thread::spawn(move || {
            sched.deploy(&format!("r{i}"), small_graph(), cfg("cluster-only", Strategy::Ftl))
        }));
    }
    for h in handles {
        let outcome = h.join().unwrap().unwrap();
        assert!(matches!(outcome, BatchOutcome::Served(_)), "block policy must serve everyone");
    }
    let stats = sched.stats();
    assert_eq!(stats.shed, 0, "block policy must never shed");
    // At least the first (cold) request is batched; later ones may take
    // the warm fast path once the key is cached.
    assert!((1..=4).contains(&stats.batched_requests), "batched: {}", stats.batched_requests);
    assert_eq!(sched.service().stats().solves, 1, "identical blocked requests still share one solve");
}

#[test]
fn warm_requests_bypass_the_queue_entirely() {
    let service = Arc::new(PlanService::new(opts(8, 2, 1)));
    let sched = BatchScheduler::new(service.clone(), batch_opts(8, 0, AdmissionPolicy::Block));
    let cold = sched.deploy("cold", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    assert!(matches!(cold, BatchOutcome::Served(_)));
    assert_eq!(sched.stats().batched_requests, 1);
    let warm = sched.deploy("warm", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    let reply = warm.served().expect("warm request must be served");
    assert!(reply.cached && reply.sim_cached);
    assert_eq!(reply.report.workload, "warm");
    assert_eq!(sched.stats().batched_requests, 1, "fully warm requests must skip the batch queue");
    assert_eq!(service.stats().solves, 1);
    assert_eq!(service.stats().sims, 1);
}

#[test]
fn blocked_submitter_times_out_at_its_deadline() {
    // Capacity 1 + a long window: the occupant pins the queue, so a
    // deadlined Block-policy submitter parks — and must be released by
    // its own deadline, not by the queue finally draining.
    let sched = Arc::new(BatchScheduler::new(
        Arc::new(PlanService::new(opts(4, 1, 1))),
        batch_opts(1, 2_000, AdmissionPolicy::Block),
    ));
    let occupant = {
        let sched = sched.clone();
        std::thread::spawn(move || sched.deploy("occupant", small_graph(), cfg("cluster-only", Strategy::Ftl)))
    };
    let start = std::time::Instant::now();
    while sched.stats().queue_depth == 0
        && sched.stats().batched_requests == 0
        && start.elapsed() < Duration::from_secs(10)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    let t = std::time::Instant::now();
    let outcome = sched
        .deploy_with_deadline(
            "deadlined",
            small_graph(),
            cfg("cluster-only", Strategy::Ftl),
            Some(Duration::from_millis(50)),
        )
        .unwrap();
    assert!(matches!(outcome, BatchOutcome::TimedOut), "blocked submitter must honour its deadline");
    assert!(t.elapsed() < Duration::from_millis(1_900), "timeout must fire before the queue drains");
    assert!(sched.stats().timeouts >= 1);
    let first = occupant.join().unwrap().unwrap();
    assert!(matches!(first, BatchOutcome::Served(_)));
}

#[test]
fn batch_fans_out_one_solve_one_sim_for_shared_fingerprint() {
    // A generous window lets all requests land in one batch; the
    // counters hold even if the OS splits them (caches + single-flight).
    let service = Arc::new(PlanService::new(opts(16, 4, 1)));
    let sched = Arc::new(BatchScheduler::new(service.clone(), batch_opts(32, 200, AdmissionPolicy::Block)));
    const N: usize = 6;
    let cycles: Vec<u64> = {
        let mut handles = Vec::new();
        for i in 0..N {
            let sched = sched.clone();
            handles.push(std::thread::spawn(move || {
                let outcome = sched
                    .deploy(&format!("req{i}"), small_graph(), cfg("cluster-only", Strategy::Ftl))
                    .unwrap();
                let reply = outcome.served().expect("must be served");
                assert_eq!(reply.report.workload, format!("req{i}"), "fan-out must keep per-request labels");
                reply.report.sim.total_cycles
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    };
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "fanned-out replies must agree");
    let stats = service.stats();
    assert_eq!(stats.solves, 1, "one batch of identical requests must solve exactly once");
    assert_eq!(stats.sims, 1, "one batch of identical requests must simulate exactly once");
    let bstats = sched.stats();
    // A straggler may take the warm fast path after the batch resolves;
    // the solve/sim counters above are the exact invariant.
    assert!((1..=N as u64).contains(&bstats.batched_requests));
    assert!(bstats.max_batch_size >= 1);
    assert_eq!(bstats.shed + bstats.timeouts, 0);
}

#[test]
fn mixed_soc_burst_solves_once_per_distinct_fingerprint() {
    let service = Arc::new(PlanService::new(opts(16, 4, 1)));
    let sched = Arc::new(BatchScheduler::new(service.clone(), batch_opts(32, 100, AdmissionPolicy::Block)));
    let mix =
        [("cluster-only", Strategy::Ftl), ("cluster-only", Strategy::LayerPerLayer), ("siracusa", Strategy::Ftl)];
    let mut handles = Vec::new();
    for round in 0..3 {
        for (soc, strategy) in mix {
            let sched = sched.clone();
            handles.push(std::thread::spawn(move || {
                let outcome =
                    sched.deploy(&format!("{soc}-{round}"), small_graph(), cfg(soc, strategy)).unwrap();
                assert!(matches!(outcome, BatchOutcome::Served(_)));
            }));
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = service.stats();
    assert_eq!(stats.solves, 3, "one solve per distinct fingerprint across the burst");
    assert_eq!(stats.sims, 3, "one simulation per distinct fingerprint across the burst");
    // Each distinct fingerprint's first (cold) request must be batched;
    // repeats may resolve via fan-out, the caches, or the fast path.
    assert!((3..=9).contains(&sched.stats().batched_requests));
}

#[test]
fn stats_json_reports_batch_shed_and_sim_cache() {
    let sched = BatchScheduler::new(
        Arc::new(PlanService::new(opts(4, 1, 1))),
        batch_opts(0, 0, AdmissionPolicy::Shed),
    );
    sched.deploy("shed-me", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    let j = sched.stats_json();
    let batch = j.get("batch").unwrap();
    assert_eq!(batch.get("shed").unwrap().as_usize().unwrap(), 1);
    assert!(batch.get("mean_batch_size").is_ok());
    assert!(j.get("sim_cache").unwrap().get("hit_rate").is_ok());
    assert!(j.get("plan_cache").is_ok());
}

// ------------------------------------------------------------------ CLI path

#[test]
fn cli_serve_self_test_passes() {
    let exe = env!("CARGO_BIN_EXE_ftl");
    let out = std::process::Command::new(exe)
        .args(["serve", "--self-test", "--cache-cap", "8", "--workers", "2"])
        .output()
        .expect("run ftl serve --self-test");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "ftl serve --self-test failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("self-test OK"), "unexpected output:\n{stdout}");
}
