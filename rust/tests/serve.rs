//! Integration tests for the `ftl::serve` layer: fingerprint contract
//! (including golden vectors pinning the canonical encoding), LRU
//! eviction, single-flight coalescing under real concurrency, plan
//! sharing, the batching scheduler (admission control, deadlines,
//! fan-out), the sim-report cache, the persistent warm-start snapshot
//! layer, and the `ftl serve --self-test` CLI paths.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use ftl::config::DeployConfig;
use ftl::coordinator::experiments;
use ftl::serve::{
    checksum, fingerprint, soc_fingerprint, AdmissionPolicy, BatchOptions, BatchOutcome, BatchScheduler, Fingerprint,
    LruCache, PersistOptions, PlanService, SNAPSHOT_FORMAT, ServeOptions, SingleFlight, SnapshotFormat, Snapshotter,
};
use ftl::tiling::Strategy;
use ftl::Graph;

fn small_graph() -> Graph {
    experiments::vit_mlp_stage(16, 24, 48)
}

fn cfg(soc: &str, strategy: Strategy) -> DeployConfig {
    DeployConfig::preset(soc, strategy).unwrap()
}

fn opts(cache_capacity: usize, cache_shards: usize, workers: usize) -> ServeOptions {
    ServeOptions { cache_capacity, cache_shards, workers, ..ServeOptions::default() }
}

// ---------------------------------------------------------------- fingerprint

#[test]
fn fingerprint_stable_across_rebuilds_and_runs_of_the_encoder() {
    let c = cfg("siracusa", Strategy::Ftl);
    let a = fingerprint(&small_graph(), &c);
    let b = fingerprint(&small_graph(), &c);
    assert_eq!(a, b, "structurally identical requests must share a key");
}

#[test]
fn fingerprint_ignores_names_but_not_structure() {
    let c = cfg("siracusa", Strategy::Ftl);
    let g = small_graph();
    let base = fingerprint(&g, &c);

    // Renaming every tensor/node is cosmetic: same key.
    let mut renamed = g.clone();
    for t in &mut renamed.tensors {
        t.name.push_str("_x");
    }
    for n in &mut renamed.nodes {
        n.name.push_str("_x");
    }
    assert_eq!(base, fingerprint(&renamed, &c));

    // Any shape change is structural: new key.
    assert_ne!(base, fingerprint(&experiments::vit_mlp_stage(16, 24, 64), &c));
    assert_ne!(base, fingerprint(&experiments::vit_mlp_stage(17, 24, 48), &c));
}

#[test]
fn fingerprint_discriminates_every_config_knob() {
    let g = small_graph();
    let base = fingerprint(&g, &cfg("siracusa", Strategy::Ftl));
    let mut keys = vec![base];

    keys.push(fingerprint(&g, &cfg("siracusa", Strategy::LayerPerLayer)));
    keys.push(fingerprint(&g, &cfg("cluster-only", Strategy::Ftl)));

    let mut dbuf = cfg("siracusa", Strategy::Ftl);
    dbuf.double_buffer = true;
    keys.push(fingerprint(&g, &dbuf));

    let mut perf = cfg("siracusa", Strategy::Ftl);
    perf.solver.use_perf_constraints = false;
    keys.push(fingerprint(&g, &perf));

    let mut budget = cfg("siracusa", Strategy::Ftl);
    budget.solver.l1_budget_fraction = 0.5;
    keys.push(fingerprint(&g, &budget));

    let mut homes = cfg("siracusa", Strategy::Ftl);
    homes.homes = ftl::tiling::HomesPolicy::Lifetime;
    keys.push(fingerprint(&g, &homes));

    let distinct: std::collections::BTreeSet<u128> = keys.iter().map(|k| k.0).collect();
    assert_eq!(distinct.len(), keys.len(), "every planning knob must produce a distinct key");
}

#[test]
fn golden_fingerprint_vectors_pin_the_canonical_encoding() {
    // Exact digests of the canonical byte encoding, independently derived
    // from the documented FNV-1a/128 scheme. If any assertion here fires,
    // the encoding changed — which silently invalidates every persisted
    // snapshot and every cross-replica shared key. If the change is
    // intentional, bump the relevant version tags (SNAPSHOT_FORMAT, the
    // "ftl-plan-v1"/"ftl-soc-v1" domain tags) and re-derive these vectors;
    // never let the encoding drift unversioned.
    let g = small_graph(); // vit_mlp_stage(16, 24, 48)
    let siracusa_ftl = fingerprint(&g, &cfg("siracusa", Strategy::Ftl));
    assert_eq!(siracusa_ftl.hex(), "42aad40208726062841a6df9f2fcc962");
    let cluster_baseline = fingerprint(&g, &cfg("cluster-only", Strategy::LayerPerLayer));
    assert_eq!(cluster_baseline.hex(), "0b7e7b01b9c50f23ee421bbf0b427e0a");
    assert_eq!(soc_fingerprint(&cfg("siracusa", Strategy::Ftl).soc).hex(), "484a0be8e0be53e4b8aaa0ef690d902a");
    assert_eq!(soc_fingerprint(&cfg("cluster-only", Strategy::Ftl).soc).hex(), "8a1cd28eece50f7d0f84f9476da177b7");
    // Derived (sim-cache) keys and snapshot checksums are pinned too —
    // both feed persisted artifacts.
    assert_eq!(siracusa_ftl.derive("ftl-sim-v1").hex(), "0207d4ee386f5c2b99d1a5114b0fcf7c");
    assert_eq!(checksum(b"ftl golden vector").hex(), "573e90f18bb28d20cdf5f7e1002e951f");
}

#[test]
fn golden_binary_fixture_pins_the_ftl_bin_v1_codec() {
    // Byte-for-byte fixture for the `ftl-bin-v1` binary snapshot codec,
    // hand-assembled from the documented wire layout (LEB128 varints,
    // length-prefixed strings, canonical field order). Like the
    // fingerprint vectors above, this pins persisted artifacts: if an
    // assertion here fires, the binary encoding changed, which
    // invalidates every written segment — if intentional, bump
    // `SEGMENT_FORMAT` and re-derive the fixture; never let the wire
    // format drift unversioned.
    use ftl::dma::DmaStats;
    use ftl::memory::Level;
    use ftl::sim::{Boundedness, PhaseReport, SimReport};
    use ftl::util::bincode::{BinReader, BinWriter};

    let mut dma = DmaStats::default();
    dma.transfers.insert(Level::L1, 2);
    dma.bytes.insert(Level::L3, 300);
    dma.bytes_in = 128;
    dma.bytes_out = 64;
    let report = SimReport {
        total_cycles: 300,
        phases: vec![PhaseReport {
            name: "mlp".into(),
            cycles: 300,
            cluster_busy: 200,
            npu_busy: 0,
            dma_l2_busy: 150,
            dma_l3_busy: 1,
            bound: Boundedness::Dma,
            dma: dma.clone(),
        }],
        dma,
    };

    // DmaStats: three (level-name, u64) maps, then the in/out byte split.
    let dma_bytes = |out: &mut Vec<u8>| {
        out.extend([1, 2]); // transfers: 1 entry, "L1"
        out.extend(b"L1");
        out.push(2); // 2 transfers
        out.extend([1, 2]); // bytes: 1 entry, "L3"
        out.extend(b"L3");
        out.extend([0xAC, 0x02]); // 300 (LEB128: 0xAC 0x02)
        out.push(0); // busy_cycles: empty map
        out.extend([0x80, 0x01]); // bytes_in 128
        out.push(64); // bytes_out 64
    };
    let mut expect: Vec<u8> = Vec::new();
    expect.extend([0xAC, 0x02]); // total_cycles 300
    expect.push(1); // one phase
    expect.push(3); // name "mlp"
    expect.extend(b"mlp");
    expect.extend([0xAC, 0x02]); // cycles 300
    expect.extend([0xC8, 0x01]); // cluster_busy 200
    expect.push(0); // npu_busy 0
    expect.extend([0x96, 0x01]); // dma_l2_busy 150
    expect.push(1); // dma_l3_busy 1
    expect.push(9); // bound "dma-bound"
    expect.extend(b"dma-bound");
    dma_bytes(&mut expect); // per-phase DMA stats
    dma_bytes(&mut expect); // whole-run DMA stats

    let mut w = BinWriter::new();
    report.to_bin(&mut w);
    let bytes = w.into_bytes();
    assert_eq!(bytes, expect, "ftl-bin-v1 SimReport encoding drifted from the pinned wire layout");

    let mut r = BinReader::new(&bytes);
    let back = SimReport::from_bin(&mut r).unwrap();
    assert!(r.is_done(), "decode must consume the fixture exactly");
    assert_eq!(back, report, "pinned bytes must decode back to the original report");
}

// ----------------------------------------------------------------------- LRU

#[test]
fn lru_evicts_in_recency_order() {
    let cache: LruCache<&'static str> = LruCache::new(2, 1);
    cache.insert(Fingerprint(1), "one");
    cache.insert(Fingerprint(2), "two");
    assert_eq!(cache.get(Fingerprint(1)), Some("one")); // 1 newer than 2
    cache.insert(Fingerprint(3), "three");
    assert!(cache.contains(Fingerprint(1)));
    assert!(!cache.contains(Fingerprint(2)), "least-recently-used entry must go first");
    assert!(cache.contains(Fingerprint(3)));
    let stats = cache.stats();
    assert_eq!(stats.evictions, 1);
    assert_eq!(stats.entries, 2);
}

#[test]
fn service_eviction_forces_resolve() {
    // Capacity 1: alternating keys always evict each other.
    let svc = PlanService::new(opts(1, 1, 1));
    let g = small_graph();
    let a = cfg("cluster-only", Strategy::Ftl);
    let b = cfg("cluster-only", Strategy::LayerPerLayer);
    svc.plan(&g, &a).unwrap();
    svc.plan(&g, &b).unwrap(); // evicts a
    svc.plan(&g, &a).unwrap(); // must re-solve
    let stats = svc.stats();
    assert_eq!(stats.solves, 3);
    assert!(stats.cache.evictions >= 2);
}

// -------------------------------------------------------------- single-flight

#[test]
fn n_concurrent_identical_requests_one_solve() {
    let svc = PlanService::new(opts(16, 4, 1));
    let g = small_graph();
    let c = cfg("cluster-only", Strategy::Ftl);
    const N: usize = 8;
    let cycles: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|_| s.spawn(|| svc.deploy("t", &g, &c).unwrap().report.sim.total_cycles))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "all coalesced replies must agree");
    let stats = svc.stats();
    assert_eq!(stats.solves, 1, "N concurrent identical requests must perform exactly 1 solve");
    assert_eq!(stats.sims, 1, "N concurrent identical requests must perform exactly 1 simulation");
    assert_eq!(stats.requests, N as u64);
}

#[test]
fn singleflight_counts_leader_and_followers() {
    let sf: SingleFlight<usize> = SingleFlight::new();
    let runs = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..6 {
            s.spawn(|| {
                let (res, _) = sf.run(9, || {
                    runs.fetch_add(1, Ordering::SeqCst);
                    let start = std::time::Instant::now();
                    while sf.waits() < 5 && start.elapsed() < std::time::Duration::from_secs(10) {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Ok(7)
                });
                assert_eq!(res.unwrap(), 7);
            });
        }
    });
    assert_eq!(runs.load(Ordering::SeqCst), 1);
    assert_eq!(sf.leads(), 1);
    assert_eq!(sf.waits(), 5);
}

// ------------------------------------------------------------- plan sharing

#[test]
fn served_plans_are_shared_not_copied() {
    let svc = PlanService::with_defaults();
    let g = small_graph();
    let c = cfg("cluster-only", Strategy::Ftl);
    let first = svc.plan(&g, &c).unwrap();
    let second = svc.plan(&g, &c).unwrap();
    assert!(Arc::ptr_eq(&first.plan, &second.plan), "warm hits must share one Arc<Deployment>");
    assert!(!first.cached && second.cached);
    // The shared plan still produces per-request reports.
    let report = first.plan.report("relabelled", &c).unwrap();
    assert_eq!(report.workload, "relabelled");
    assert!(report.sim.total_cycles > 0);
}

#[test]
fn cached_plan_report_matches_direct_pipeline() {
    let svc = PlanService::with_defaults();
    let g = small_graph();
    let c = cfg("siracusa", Strategy::Ftl);
    let via_cache = svc.deploy("w", &g, &c).unwrap();
    let (_, direct) = ftl::Deployer::new(g.clone(), c.clone()).with_workload_name("w").deploy().unwrap();
    assert_eq!(via_cache.report.sim.total_cycles, direct.sim.total_cycles);
    assert_eq!(via_cache.report.dma_bytes, direct.dma_bytes);
    assert_eq!(via_cache.report.peak_l1, direct.peak_l1);
}

// ----------------------------------------------------------- sim-report cache

#[test]
fn sim_reports_cached_by_plan_fingerprint() {
    let svc = PlanService::with_defaults();
    let g = small_graph();
    let c = cfg("cluster-only", Strategy::Ftl);
    let cold = svc.deploy("first", &g, &c).unwrap();
    assert!(!cold.sim_cached, "first deploy must run the engine");
    let warm = svc.deploy("second", &g, &c).unwrap();
    assert!(warm.sim_cached, "repeat deploy must hit the sim cache");
    assert_eq!(warm.report.sim.total_cycles, cold.report.sim.total_cycles);
    assert_eq!(warm.report.workload, "second", "cached sim must not leak the first workload label");
    let stats = svc.stats();
    assert_eq!(stats.sims, 1);
    assert_eq!(stats.sim_cache.hits, 1);
    assert_eq!(stats.sim_cache.misses, 1);
    assert!(stats.sim_cache.hit_rate() > 0.49);
}

// ----------------------------------------------------------- batch scheduler

fn batch_opts(queue_capacity: usize, window_ms: u64, policy: AdmissionPolicy) -> BatchOptions {
    BatchOptions {
        queue_capacity,
        batch_window: Duration::from_millis(window_ms),
        policy,
        ..BatchOptions::default()
    }
}

#[test]
fn zero_capacity_queue_sheds_under_both_policies() {
    for policy in [AdmissionPolicy::Shed, AdmissionPolicy::Block] {
        let sched = BatchScheduler::new(
            Arc::new(PlanService::new(opts(4, 1, 1))),
            batch_opts(0, 0, policy),
        );
        let outcome = sched.deploy("z", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
        assert!(matches!(outcome, BatchOutcome::Shed), "zero-capacity must shed under {policy:?}");
        assert_eq!(sched.stats().shed, 1);
        assert_eq!(sched.service().stats().solves, 0);
    }
}

#[test]
fn deadline_expired_at_enqueue_times_out_without_solving() {
    let sched = BatchScheduler::new(Arc::new(PlanService::new(opts(4, 1, 1))), batch_opts(8, 0, AdmissionPolicy::Shed));
    let outcome = sched
        .deploy_with_deadline("late", small_graph(), cfg("cluster-only", Strategy::Ftl), Some(Duration::ZERO))
        .unwrap();
    assert!(matches!(outcome, BatchOutcome::TimedOut));
    let stats = sched.stats();
    assert_eq!(stats.timeouts, 1);
    assert_eq!(stats.batched_requests, 0, "a pre-expired request must never enter the queue");
    assert_eq!(sched.service().stats().requests, 0);
}

#[test]
fn full_queue_sheds_with_shed_policy() {
    // Capacity 1 + a long batch window: the first request sits in the
    // queue for the whole window, so the second arrives at a full queue.
    let sched = Arc::new(BatchScheduler::new(
        Arc::new(PlanService::new(opts(4, 1, 1))),
        batch_opts(1, 1_000, AdmissionPolicy::Shed),
    ));
    let occupant = {
        let sched = sched.clone();
        std::thread::spawn(move || sched.deploy("occupant", small_graph(), cfg("cluster-only", Strategy::Ftl)))
    };
    // Wait until the occupant actually occupies the queue (or is being
    // collected — either way depth+batched covers it).
    let start = std::time::Instant::now();
    while sched.stats().queue_depth == 0
        && sched.stats().batched_requests == 0
        && start.elapsed() < Duration::from_secs(10)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    let outcome = sched.deploy("overflow", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    assert!(matches!(outcome, BatchOutcome::Shed), "full queue must shed instead of blocking");
    assert_eq!(sched.stats().shed, 1);
    let first = occupant.join().unwrap().unwrap();
    assert!(matches!(first, BatchOutcome::Served(_)), "the occupant must still be served");
}

#[test]
fn full_queue_blocks_then_serves_with_block_policy() {
    let sched = Arc::new(BatchScheduler::new(
        Arc::new(PlanService::new(opts(4, 1, 1))),
        batch_opts(1, 50, AdmissionPolicy::Block),
    ));
    let mut handles = Vec::new();
    for i in 0..4 {
        let sched = sched.clone();
        handles.push(std::thread::spawn(move || {
            sched.deploy(&format!("r{i}"), small_graph(), cfg("cluster-only", Strategy::Ftl))
        }));
    }
    for h in handles {
        let outcome = h.join().unwrap().unwrap();
        assert!(matches!(outcome, BatchOutcome::Served(_)), "block policy must serve everyone");
    }
    let stats = sched.stats();
    assert_eq!(stats.shed, 0, "block policy must never shed");
    // At least the first (cold) request is batched; later ones may take
    // the warm fast path once the key is cached.
    assert!((1..=4).contains(&stats.batched_requests), "batched: {}", stats.batched_requests);
    assert_eq!(sched.service().stats().solves, 1, "identical blocked requests still share one solve");
}

#[test]
fn warm_requests_bypass_the_queue_entirely() {
    let service = Arc::new(PlanService::new(opts(8, 2, 1)));
    let sched = BatchScheduler::new(service.clone(), batch_opts(8, 0, AdmissionPolicy::Block));
    let cold = sched.deploy("cold", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    assert!(matches!(cold, BatchOutcome::Served(_)));
    assert_eq!(sched.stats().batched_requests, 1);
    let warm = sched.deploy("warm", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    let reply = warm.served().expect("warm request must be served");
    assert!(reply.cached && reply.sim_cached);
    assert_eq!(reply.report.workload, "warm");
    assert_eq!(sched.stats().batched_requests, 1, "fully warm requests must skip the batch queue");
    assert_eq!(service.stats().solves, 1);
    assert_eq!(service.stats().sims, 1);
}

#[test]
fn blocked_submitter_times_out_at_its_deadline() {
    // Capacity 1 + a long window: the occupant pins the queue, so a
    // deadlined Block-policy submitter parks — and must be released by
    // its own deadline, not by the queue finally draining.
    let sched = Arc::new(BatchScheduler::new(
        Arc::new(PlanService::new(opts(4, 1, 1))),
        batch_opts(1, 2_000, AdmissionPolicy::Block),
    ));
    let occupant = {
        let sched = sched.clone();
        std::thread::spawn(move || sched.deploy("occupant", small_graph(), cfg("cluster-only", Strategy::Ftl)))
    };
    let start = std::time::Instant::now();
    while sched.stats().queue_depth == 0
        && sched.stats().batched_requests == 0
        && start.elapsed() < Duration::from_secs(10)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    let t = std::time::Instant::now();
    let outcome = sched
        .deploy_with_deadline(
            "deadlined",
            small_graph(),
            cfg("cluster-only", Strategy::Ftl),
            Some(Duration::from_millis(50)),
        )
        .unwrap();
    assert!(matches!(outcome, BatchOutcome::TimedOut), "blocked submitter must honour its deadline");
    assert!(t.elapsed() < Duration::from_millis(1_900), "timeout must fire before the queue drains");
    assert!(sched.stats().timeouts >= 1);
    let first = occupant.join().unwrap().unwrap();
    assert!(matches!(first, BatchOutcome::Served(_)));
}

#[test]
fn batch_fans_out_one_solve_one_sim_for_shared_fingerprint() {
    // A generous window lets all requests land in one batch; the
    // counters hold even if the OS splits them (caches + single-flight).
    let service = Arc::new(PlanService::new(opts(16, 4, 1)));
    let sched = Arc::new(BatchScheduler::new(service.clone(), batch_opts(32, 200, AdmissionPolicy::Block)));
    const N: usize = 6;
    let cycles: Vec<u64> = {
        let mut handles = Vec::new();
        for i in 0..N {
            let sched = sched.clone();
            handles.push(std::thread::spawn(move || {
                let outcome = sched
                    .deploy(&format!("req{i}"), small_graph(), cfg("cluster-only", Strategy::Ftl))
                    .unwrap();
                let reply = outcome.served().expect("must be served");
                assert_eq!(reply.report.workload, format!("req{i}"), "fan-out must keep per-request labels");
                reply.report.sim.total_cycles
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    };
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "fanned-out replies must agree");
    let stats = service.stats();
    assert_eq!(stats.solves, 1, "one batch of identical requests must solve exactly once");
    assert_eq!(stats.sims, 1, "one batch of identical requests must simulate exactly once");
    let bstats = sched.stats();
    // A straggler may take the warm fast path after the batch resolves;
    // the solve/sim counters above are the exact invariant.
    assert!((1..=N as u64).contains(&bstats.batched_requests));
    assert!(bstats.max_batch_size >= 1);
    assert_eq!(bstats.shed + bstats.timeouts, 0);
}

#[test]
fn mixed_soc_burst_solves_once_per_distinct_fingerprint() {
    let service = Arc::new(PlanService::new(opts(16, 4, 1)));
    let sched = Arc::new(BatchScheduler::new(service.clone(), batch_opts(32, 100, AdmissionPolicy::Block)));
    let mix =
        [("cluster-only", Strategy::Ftl), ("cluster-only", Strategy::LayerPerLayer), ("siracusa", Strategy::Ftl)];
    let mut handles = Vec::new();
    for round in 0..3 {
        for (soc, strategy) in mix {
            let sched = sched.clone();
            handles.push(std::thread::spawn(move || {
                let outcome =
                    sched.deploy(&format!("{soc}-{round}"), small_graph(), cfg(soc, strategy)).unwrap();
                assert!(matches!(outcome, BatchOutcome::Served(_)));
            }));
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = service.stats();
    assert_eq!(stats.solves, 3, "one solve per distinct fingerprint across the burst");
    assert_eq!(stats.sims, 3, "one simulation per distinct fingerprint across the burst");
    // Each distinct fingerprint's first (cold) request must be batched;
    // repeats may resolve via fan-out, the caches, or the fast path.
    assert!((3..=9).contains(&sched.stats().batched_requests));
}

#[test]
fn stats_json_reports_batch_shed_and_sim_cache() {
    let sched = BatchScheduler::new(
        Arc::new(PlanService::new(opts(4, 1, 1))),
        batch_opts(0, 0, AdmissionPolicy::Shed),
    );
    sched.deploy("shed-me", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    let j = sched.stats_json();
    let batch = j.get("batch").unwrap();
    assert_eq!(batch.get("shed").unwrap().as_usize().unwrap(), 1);
    assert!(batch.get("mean_batch_size").is_ok());
    assert!(j.get("sim_cache").unwrap().get("hit_rate").is_ok());
    // The global solver pool's search counters ride along in STATS.
    let solver = j.get("solver").unwrap();
    assert!(solver.get("threads").unwrap().as_usize().unwrap() >= 1);
    for key in ["solves", "space", "scored", "capacity_pruned", "bound_pruned", "subtrees_cut"] {
        assert!(solver.get(key).is_ok(), "solver stats must expose '{key}'");
    }
    assert!(j.get("plan_cache").is_ok());
}

// -------------------------------------------------------- persistence layer

/// Fresh, empty snapshot dir for one test (attach() creates it).
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ftl-serve-persist-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn warm_start_restarted_service_serves_with_zero_solves_and_sims() {
    let dir = temp_dir("warm-start");
    let g = small_graph();
    let a = cfg("cluster-only", Strategy::Ftl);
    let b = cfg("siracusa", Strategy::Ftl);
    let (cycles_a, cycles_b) = {
        let svc = Arc::new(PlanService::new(opts(16, 2, 1)));
        let snap = Snapshotter::attach(svc.clone(), &dir, PersistOptions::manual()).unwrap();
        let ra = svc.deploy("first", &g, &a).unwrap();
        let rb = svc.deploy("second", &g, &b).unwrap();
        assert_eq!(snap.flush(), 4, "two plans + two sim reports must be snapshotted");
        assert_eq!(snap.counters().write_errors(), 0);
        (ra.report.sim.total_cycles, rb.report.sim.total_cycles)
    };

    // "Restart": a fresh service (fresh caches, fresh counters) over the
    // same directory — the acceptance-criteria scenario.
    let svc = Arc::new(PlanService::new(opts(16, 2, 1)));
    let snap = Snapshotter::attach(svc.clone(), &dir, PersistOptions::manual()).unwrap();
    assert_eq!(snap.counters().loaded(), 4, "restart must load every snapshot entry");
    let reply = svc.deploy("after-restart", &g, &a).unwrap();
    assert!(reply.cached && reply.sim_cached, "restarted service must hit both loaded caches");
    assert_eq!(reply.report.workload, "after-restart");
    assert_eq!(reply.report.sim.total_cycles, cycles_a, "loaded snapshot must reproduce the original report");
    assert_eq!(svc.stats().solves, 0, "warm start must perform zero solves");
    assert_eq!(svc.stats().sims, 0, "warm start must perform zero simulator runs");

    // Same guarantee through the batch scheduler (the `ftl serve` path):
    // a fully warm request takes the fast path without queueing.
    let sched = BatchScheduler::new(svc.clone(), BatchOptions::default());
    let outcome = sched.deploy("batched", g.clone(), b).unwrap();
    let reply = outcome.served().expect("warm request must be served");
    assert!(reply.cached && reply.sim_cached);
    assert_eq!(reply.report.sim.total_cycles, cycles_b);
    assert_eq!(svc.stats().solves, 0);
    assert_eq!(svc.stats().sims, 0);
    assert_eq!(sched.stats().batched_requests, 0, "fully warm restart traffic must bypass the queue");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_start_binary_segments_serve_identically_and_pass_the_verify_gate() {
    // The binary-codec flavour of the acceptance scenario: a replica
    // snapshotting with `--snapshot-format bin` restarts warm, serves
    // byte-identical reports, and its loaded entries pass the
    // `--verify-plans` gate.
    let dir = temp_dir("warm-start-bin");
    let g = small_graph();
    let a = cfg("cluster-only", Strategy::Ftl);
    let b = cfg("siracusa", Strategy::Ftl);
    let bin_opts = || PersistOptions::manual().with_format(SnapshotFormat::Bin);
    let cycles_a = {
        let svc = Arc::new(PlanService::new(opts(16, 2, 1)));
        let snap = Snapshotter::attach(svc.clone(), &dir, bin_opts()).unwrap();
        let ra = svc.deploy("first", &g, &a).unwrap();
        svc.deploy("second", &g, &b).unwrap();
        assert_eq!(snap.flush(), 4, "two plans + two sim reports must be snapshotted");
        assert_eq!(snap.counters().write_errors(), 0);
        ra.report.sim.total_cycles
    };

    // The directory holds appended segments, not per-entry JSON files.
    let names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_str().unwrap().to_string())
        .collect();
    assert!(names.iter().any(|n| n.ends_with(".ftlseg")), "binary snapshots must write segment files: {names:?}");
    assert!(!names.iter().any(|n| n.ends_with(".json")), "binary snapshots must not write per-entry JSON: {names:?}");

    // Restart with the verify gate on: every loaded plan is checked and
    // none may be rejected — a snapshot round-trip must not damage plans.
    let svc = Arc::new(PlanService::new(ServeOptions { verify_plans: true, ..opts(16, 2, 1) }));
    let snap = Snapshotter::attach(svc.clone(), &dir, bin_opts()).unwrap();
    assert_eq!(snap.counters().loaded(), 4, "restart must load every segment entry");
    let reply = svc.deploy("after-restart", &g, &a).unwrap();
    assert!(reply.cached && reply.sim_cached, "restarted service must hit both loaded caches");
    assert_eq!(reply.report.sim.total_cycles, cycles_a, "loaded segment must reproduce the original report");
    assert_eq!(svc.stats().solves, 0, "warm start must perform zero solves");
    assert_eq!(svc.stats().sims, 0, "warm start must perform zero simulator runs");
    let j = svc.stats_json();
    let verify = j.get("verify").unwrap();
    assert_eq!(verify.get("checked").unwrap().as_usize().unwrap(), 2, "both loaded plans must be verified");
    assert_eq!(verify.get("rejected").unwrap().as_usize().unwrap(), 0, "loaded plans must pass the verifier");

    // Reads are format-agnostic: a JSON-configured replica pointed at the
    // same directory loads the segments all the same.
    let svc = Arc::new(PlanService::new(opts(16, 2, 1)));
    let snap = Snapshotter::attach(svc.clone(), &dir, PersistOptions::manual()).unwrap();
    assert_eq!(snap.counters().loaded(), 4, "segment entries must load regardless of the configured format");
    assert_eq!(svc.stats().cache.entries, 2);
    assert_eq!(svc.stats().sim_cache.entries, 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_and_version_mismatched_entries_are_skipped_never_fatal() {
    let dir = temp_dir("corrupt");
    let g = small_graph();
    let c = cfg("cluster-only", Strategy::Ftl);
    {
        let svc = Arc::new(PlanService::new(opts(8, 1, 1)));
        let snap = Snapshotter::attach(svc.clone(), &dir, PersistOptions::manual()).unwrap();
        svc.deploy("seed", &g, &c).unwrap();
        assert_eq!(snap.flush(), 2);
    }
    // Damage the plan entry, drop in a garbage file, and add a
    // version-mismatched sim entry; the original sim entry stays intact.
    let files: Vec<PathBuf> = std::fs::read_dir(&dir).unwrap().map(|e| e.unwrap().path()).collect();
    let name = |p: &PathBuf| p.file_name().unwrap().to_str().unwrap().to_string();
    let plan_file = files.iter().find(|p| name(p).starts_with("plan-")).unwrap();
    std::fs::write(plan_file, "{\"format\":\"ftl-snapshot-v1\", truncated mid-write").unwrap();
    std::fs::write(dir.join("plan-00000000000000000000000000000000.json"), "not json at all").unwrap();
    let sim_file = files.iter().find(|p| name(p).starts_with("sim-")).unwrap();
    let versioned = std::fs::read_to_string(sim_file).unwrap().replace(SNAPSHOT_FORMAT, "ftl-snapshot-v999");
    std::fs::write(dir.join("sim-11111111111111111111111111111111.json"), versioned).unwrap();

    let svc = Arc::new(PlanService::new(opts(8, 1, 1)));
    let snap = Snapshotter::attach(svc.clone(), &dir, PersistOptions::manual()).unwrap();
    assert_eq!(snap.counters().loaded(), 1, "the intact sim entry must still load");
    assert_eq!(snap.counters().skipped_corrupt(), 2, "truncated + garbage files are corrupt skips");
    assert_eq!(snap.counters().skipped_version(), 1, "foreign format tag is a version skip");

    // Degraded but alive: the damaged plan re-solves, the intact sim
    // entry still short-circuits the simulator.
    let reply = svc.deploy("recover", &g, &c).unwrap();
    assert!(!reply.cached, "damaged plan entry must fall back to a fresh solve");
    assert!(reply.sim_cached, "intact sim entry must still serve");
    assert_eq!(svc.stats().solves, 1);
    assert_eq!(svc.stats().sims, 0);

    // persist.* counters surface in the STATS payload.
    let j = svc.stats_json();
    let persist = j.get("persist").unwrap();
    assert_eq!(persist.get("loaded").unwrap().as_usize().unwrap(), 1);
    assert_eq!(persist.get("skipped_corrupt").unwrap().as_usize().unwrap(), 2);
    assert_eq!(persist.get("skipped_version").unwrap().as_usize().unwrap(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn background_snapshotter_writes_behind_without_explicit_flush() {
    let dir = temp_dir("write-behind");
    let svc = Arc::new(PlanService::new(opts(8, 1, 1)));
    let snap = Snapshotter::attach(
        svc.clone(),
        &dir,
        PersistOptions { interval: Duration::from_millis(20), ..PersistOptions::default() },
    )
    .unwrap();
    svc.deploy("bg", &small_graph(), &cfg("cluster-only", Strategy::Ftl)).unwrap();
    let start = std::time::Instant::now();
    while snap.counters().entries_written() < 2 && start.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(
        snap.counters().entries_written() >= 2,
        "write-behind thread must persist entries without an explicit flush"
    );
    assert!(snap.counters().snapshots() >= 1);
    // No half-written files under final names.
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        let n = p.file_name().unwrap().to_str().unwrap().to_string();
        if n.ends_with(".json") {
            assert!(
                ftl::util::json::parse(&std::fs::read_to_string(&p).unwrap()).is_ok(),
                "snapshot entry {n} must be complete valid JSON"
            );
        }
    }
    snap.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deployment_and_sim_report_roundtrip_property() {
    // Round-trip property over real solved deployments: every knob
    // combination the pool covers must decode back to an identical,
    // still-servable plan. (Shapes come from a pool the solver is known
    // to handle; the knobs vary per seeded case.)
    let shapes = [(16usize, 24usize, 48usize), (32, 32, 64), (64, 32, 96)];
    ftl::util::prop::cases(6, |rng| {
        let &(seq, d, h) = rng.pick(&shapes);
        let soc = *rng.pick(&["siracusa", "cluster-only"]);
        let strategy = if rng.chance(0.5) { Strategy::Ftl } else { Strategy::LayerPerLayer };
        let mut c = cfg(soc, strategy);
        c.double_buffer = rng.chance(0.5);
        let g = experiments::vit_mlp_stage(seq, d, h);
        let plan = ftl::Deployer::new(g, c.clone()).plan().unwrap();
        let back = ftl::Deployment::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan, "deployment must round-trip ({seq}x{d}x{h}, {soc}, {strategy:?})");
        let sim = plan.simulate(&c).unwrap();
        let sim_back = ftl::sim::SimReport::from_json(&sim.to_json()).unwrap();
        assert_eq!(sim_back, sim, "sim report must round-trip");
        // The decoded plan is still servable: it re-simulates identically.
        assert_eq!(back.simulate(&c).unwrap(), sim);
    });
}

// ------------------------------------------------------------------ CLI path

#[test]
fn cli_serve_self_test_passes_and_plans_are_thread_count_invariant() {
    // Also the CI solver-determinism smoke in miniature: the self-test
    // prints a `plan_digest=` content hash over the plans it compiled;
    // a single-threaded and a multi-threaded solver run must match.
    let exe = env!("CARGO_BIN_EXE_ftl");
    let digest_with = |threads: &str| {
        let out = std::process::Command::new(exe)
            .args(["serve", "--self-test", "--cache-cap", "8", "--workers", "2"])
            .env("FTL_SOLVER_THREADS", threads)
            .output()
            .expect("run ftl serve --self-test");
        let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
        let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
        assert!(out.status.success(), "ftl serve --self-test failed:\n{stdout}\n{stderr}");
        assert!(stdout.contains("self-test OK"), "unexpected output:\n{stdout}");
        let digest = stdout
            .lines()
            .find_map(|l| l.split_once("plan_digest=").map(|(_, d)| d.trim().to_string()))
            .expect("self-test must print a plan_digest= line");
        assert_eq!(digest.len(), 32, "digest must be 32 hex digits: {digest}");
        digest
    };
    assert_eq!(
        digest_with("1"),
        digest_with("4"),
        "solver thread count must not change the compiled plans"
    );
}

#[test]
fn cli_serve_warm_start_self_test_reports_zero_solves_on_second_run() {
    // The CI warm-start smoke step in miniature: two `ftl serve
    // --self-test --cache-dir` runs against one directory. The first
    // populates the snapshot (one solve per distinct request), the second
    // must serve everything from the loaded caches.
    let dir = temp_dir("cli-warm");
    let exe = env!("CARGO_BIN_EXE_ftl");
    let run = || {
        let out = std::process::Command::new(exe)
            .args(["serve", "--self-test", "--cache-dir", dir.to_str().unwrap()])
            .output()
            .expect("run ftl serve --self-test --cache-dir");
        let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
        let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
        assert!(out.status.success(), "warm-start self-test failed:\n{stdout}\n{stderr}");
        assert!(stdout.contains("warm-start self-test OK"), "unexpected output:\n{stdout}");
        stdout
    };
    let first = run();
    assert!(first.contains("loaded=0"), "first run starts cold:\n{first}");
    assert!(first.contains("solves=3 sims=3"), "first run must solve each distinct request:\n{first}");
    let second = run();
    assert!(
        second.contains("solves=0 sims=0"),
        "second run against the populated cache dir must not solve or simulate:\n{second}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
