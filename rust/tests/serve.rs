//! Integration tests for the `ftl::serve` layer: fingerprint contract,
//! LRU eviction, single-flight coalescing under real concurrency, plan
//! sharing, and the `ftl serve --self-test` CLI path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use ftl::config::DeployConfig;
use ftl::coordinator::experiments;
use ftl::serve::{fingerprint, Fingerprint, LruCache, PlanService, ServeOptions, SingleFlight};
use ftl::tiling::Strategy;
use ftl::Graph;

fn small_graph() -> Graph {
    experiments::vit_mlp_stage(16, 24, 48)
}

fn cfg(soc: &str, strategy: Strategy) -> DeployConfig {
    DeployConfig::preset(soc, strategy).unwrap()
}

// ---------------------------------------------------------------- fingerprint

#[test]
fn fingerprint_stable_across_rebuilds_and_runs_of_the_encoder() {
    let c = cfg("siracusa", Strategy::Ftl);
    let a = fingerprint(&small_graph(), &c);
    let b = fingerprint(&small_graph(), &c);
    assert_eq!(a, b, "structurally identical requests must share a key");
}

#[test]
fn fingerprint_ignores_names_but_not_structure() {
    let c = cfg("siracusa", Strategy::Ftl);
    let g = small_graph();
    let base = fingerprint(&g, &c);

    // Renaming every tensor/node is cosmetic: same key.
    let mut renamed = g.clone();
    for t in &mut renamed.tensors {
        t.name.push_str("_x");
    }
    for n in &mut renamed.nodes {
        n.name.push_str("_x");
    }
    assert_eq!(base, fingerprint(&renamed, &c));

    // Any shape change is structural: new key.
    assert_ne!(base, fingerprint(&experiments::vit_mlp_stage(16, 24, 64), &c));
    assert_ne!(base, fingerprint(&experiments::vit_mlp_stage(17, 24, 48), &c));
}

#[test]
fn fingerprint_discriminates_every_config_knob() {
    let g = small_graph();
    let base = fingerprint(&g, &cfg("siracusa", Strategy::Ftl));
    let mut keys = vec![base];

    keys.push(fingerprint(&g, &cfg("siracusa", Strategy::LayerPerLayer)));
    keys.push(fingerprint(&g, &cfg("cluster-only", Strategy::Ftl)));

    let mut dbuf = cfg("siracusa", Strategy::Ftl);
    dbuf.double_buffer = true;
    keys.push(fingerprint(&g, &dbuf));

    let mut perf = cfg("siracusa", Strategy::Ftl);
    perf.solver.use_perf_constraints = false;
    keys.push(fingerprint(&g, &perf));

    let mut budget = cfg("siracusa", Strategy::Ftl);
    budget.solver.l1_budget_fraction = 0.5;
    keys.push(fingerprint(&g, &budget));

    let mut homes = cfg("siracusa", Strategy::Ftl);
    homes.homes = ftl::tiling::HomesPolicy::Lifetime;
    keys.push(fingerprint(&g, &homes));

    let distinct: std::collections::BTreeSet<u128> = keys.iter().map(|k| k.0).collect();
    assert_eq!(distinct.len(), keys.len(), "every planning knob must produce a distinct key");
}

// ----------------------------------------------------------------------- LRU

#[test]
fn lru_evicts_in_recency_order() {
    let cache: LruCache<&'static str> = LruCache::new(2, 1);
    cache.insert(Fingerprint(1), "one");
    cache.insert(Fingerprint(2), "two");
    assert_eq!(cache.get(Fingerprint(1)), Some("one")); // 1 newer than 2
    cache.insert(Fingerprint(3), "three");
    assert!(cache.contains(Fingerprint(1)));
    assert!(!cache.contains(Fingerprint(2)), "least-recently-used entry must go first");
    assert!(cache.contains(Fingerprint(3)));
    let stats = cache.stats();
    assert_eq!(stats.evictions, 1);
    assert_eq!(stats.entries, 2);
}

#[test]
fn service_eviction_forces_resolve() {
    // Capacity 1: alternating keys always evict each other.
    let svc = PlanService::new(ServeOptions { cache_capacity: 1, cache_shards: 1, workers: 1 });
    let g = small_graph();
    let a = cfg("cluster-only", Strategy::Ftl);
    let b = cfg("cluster-only", Strategy::LayerPerLayer);
    svc.plan(&g, &a).unwrap();
    svc.plan(&g, &b).unwrap(); // evicts a
    svc.plan(&g, &a).unwrap(); // must re-solve
    let stats = svc.stats();
    assert_eq!(stats.solves, 3);
    assert!(stats.cache.evictions >= 2);
}

// -------------------------------------------------------------- single-flight

#[test]
fn n_concurrent_identical_requests_one_solve() {
    let svc = PlanService::new(ServeOptions { cache_capacity: 16, cache_shards: 4, workers: 1 });
    let g = small_graph();
    let c = cfg("cluster-only", Strategy::Ftl);
    const N: usize = 8;
    let cycles: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|_| s.spawn(|| svc.deploy("t", &g, &c).unwrap().report.sim.total_cycles))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "all coalesced replies must agree");
    let stats = svc.stats();
    assert_eq!(stats.solves, 1, "N concurrent identical requests must perform exactly 1 solve");
    assert_eq!(stats.requests, N as u64);
}

#[test]
fn singleflight_counts_leader_and_followers() {
    let sf: SingleFlight<usize> = SingleFlight::new();
    let runs = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..6 {
            s.spawn(|| {
                let (res, _) = sf.run(9, || {
                    runs.fetch_add(1, Ordering::SeqCst);
                    let start = std::time::Instant::now();
                    while sf.waits() < 5 && start.elapsed() < std::time::Duration::from_secs(10) {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Ok(7)
                });
                assert_eq!(res.unwrap(), 7);
            });
        }
    });
    assert_eq!(runs.load(Ordering::SeqCst), 1);
    assert_eq!(sf.leads(), 1);
    assert_eq!(sf.waits(), 5);
}

// ------------------------------------------------------------- plan sharing

#[test]
fn served_plans_are_shared_not_copied() {
    let svc = PlanService::with_defaults();
    let g = small_graph();
    let c = cfg("cluster-only", Strategy::Ftl);
    let first = svc.plan(&g, &c).unwrap();
    let second = svc.plan(&g, &c).unwrap();
    assert!(Arc::ptr_eq(&first.plan, &second.plan), "warm hits must share one Arc<Deployment>");
    assert!(!first.cached && second.cached);
    // The shared plan still produces per-request reports.
    let report = first.plan.report("relabelled", &c).unwrap();
    assert_eq!(report.workload, "relabelled");
    assert!(report.sim.total_cycles > 0);
}

#[test]
fn cached_plan_report_matches_direct_pipeline() {
    let svc = PlanService::with_defaults();
    let g = small_graph();
    let c = cfg("siracusa", Strategy::Ftl);
    let via_cache = svc.deploy("w", &g, &c).unwrap();
    let (_, direct) = ftl::Deployer::new(g.clone(), c.clone()).with_workload_name("w").deploy().unwrap();
    assert_eq!(via_cache.report.sim.total_cycles, direct.sim.total_cycles);
    assert_eq!(via_cache.report.dma_bytes, direct.dma_bytes);
    assert_eq!(via_cache.report.peak_l1, direct.peak_l1);
}

// ------------------------------------------------------------------ CLI path

#[test]
fn cli_serve_self_test_passes() {
    let exe = env!("CARGO_BIN_EXE_ftl");
    let out = std::process::Command::new(exe)
        .args(["serve", "--self-test", "--cache-cap", "8", "--workers", "2"])
        .output()
        .expect("run ftl serve --self-test");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "ftl serve --self-test failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("self-test OK"), "unexpected output:\n{stdout}");
}
