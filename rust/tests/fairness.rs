//! Deterministic fairness harness for the batch scheduler's priority
//! lanes.
//!
//! Two layers, one codebase:
//!
//! * **Virtual-clock property tests** drive the *same*
//!   [`LaneSet`]/WFQ core the dispatcher uses, with seeded random
//!   weights, arrival patterns, queue caps and per-quantum costs — no
//!   threads, no wall clock, so the weighted-fairness bound is asserted
//!   exactly: every continuously backlogged lane's served cold-work
//!   share deviates from its weight share by at most one batch window
//!   of cost, and per-lane virtual time is monotone.
//! * **Scheduler regression tests** pin the degenerate configurations:
//!   a single default lane reproduces the pre-lane FIFO scheduler's
//!   outcome sequence and `batch.*` counters, a zero-capacity lane
//!   sheds everything, a deadline that expires while parked or queued
//!   in a non-default lane resolves `TIMEOUT` (never silent
//!   starvation), and the scheduler-wide totals always equal the
//!   per-lane sums (`sum(lanes.*) == batch.*`).

use std::sync::Arc;
use std::time::Duration;

use ftl::config::DeployConfig;
use ftl::coordinator::experiments;
use ftl::serve::{
    AdmissionPolicy, BatchOptions, BatchOutcome, BatchScheduler, DEFAULT_LANE, LaneSet, LaneSpec, PlanService,
    ServeOptions,
};
use ftl::tiling::Strategy;
use ftl::util::prop::{cases, Rng};
use ftl::Graph;

// ------------------------------------------------------- virtual-clock core

/// A seeded tenant set: `n` lanes named `t0..`, random weights in
/// `1..=9`, the given queue capacity each.
fn tenant_lanes(rng: &mut Rng, n: usize, capacity: usize) -> (LaneSet<u64>, Vec<usize>, Vec<u64>) {
    let weights: Vec<u64> = (0..n).map(|_| rng.range(1, 9) as u64).collect();
    let specs: Vec<LaneSpec> =
        weights.iter().enumerate().map(|(i, &w)| LaneSpec::new(format!("t{i}"), w, capacity)).collect();
    let lanes: LaneSet<u64> = LaneSet::new(specs);
    let idx: Vec<usize> = (0..n).map(|i| lanes.resolve(Some(format!("t{i}").as_str()))).collect();
    (lanes, idx, weights)
}

/// The start-time-fair-queuing deviation bound for lane `k`: one batch
/// window of cost — its own largest quantum (weighted by the competitor
/// mass) plus its weight share of the competitors' largest quanta.
/// Derived from the pairwise bound `|S_i/w_i - S_j/w_j| <= c_i/w_i +
/// c_j/w_j` for continuously backlogged lanes.
fn share_bound(k: usize, weights: &[u64], cmax: &[u64]) -> f64 {
    let wsum: f64 = weights.iter().map(|&w| w as f64).sum();
    let others: f64 = cmax.iter().enumerate().filter(|&(j, _)| j != k).map(|(_, &c)| c as f64).sum();
    cmax[k] as f64 * (wsum - weights[k] as f64) / wsum + weights[k] as f64 / wsum * others
}

#[test]
fn prop_saturated_lanes_split_cold_work_by_weight_within_one_batch_window() {
    cases(40, |rng| {
        let n = rng.range(2, 4);
        let cap = rng.range(4, 8);
        let (mut lanes, idx, weights) = tenant_lanes(rng, n, cap);
        let max_cost = rng.range(1, 5) as u64;
        let quanta = rng.range(150, 500);
        let mut served = vec![0u64; n];
        let mut cmax = vec![0u64; n];
        let mut last_tag = vec![0u128; n];
        for _ in 0..quanta {
            // Saturation: every tenant lane keeps a backlog. (The
            // arrival pattern is irrelevant as long as no lane runs
            // dry — pushes beyond capacity just bounce.)
            for &l in &idx {
                while lanes.len_of(l) < cap {
                    if lanes.try_push(l, 0).is_err() {
                        break;
                    }
                }
            }
            let lane = lanes.pick().expect("every tenant lane is backlogged");
            let batch = lanes.drain(lane, 1);
            assert_eq!(batch.len(), 1, "unit quantum");
            let cost = rng.range(1, max_cost as usize) as u64;
            lanes.charge(lane, cost);
            let k = idx.iter().position(|&x| x == lane).expect("only backlogged lanes are picked");
            served[k] += cost;
            cmax[k] = cmax[k].max(cost);
            for (j, &l) in idx.iter().enumerate() {
                assert!(lanes.vfinish(l) >= last_tag[j], "per-lane virtual time must be monotone");
                last_tag[j] = lanes.vfinish(l);
            }
        }
        let total: u64 = served.iter().sum();
        let wsum: f64 = weights.iter().map(|&w| w as f64).sum();
        for k in 0..n {
            let expected = total as f64 * weights[k] as f64 / wsum;
            let dev = (served[k] as f64 - expected).abs();
            let bound = share_bound(k, &weights, &cmax) + 1.0; // +1: fixed-point rounding slack
            assert!(
                dev <= bound,
                "lane {k} (w={}) served {} vs fluid share {expected:.2} — deviation {dev:.2} > bound {bound:.2}",
                weights[k],
                served[k]
            );
        }
    });
}

#[test]
fn prop_pairwise_fairness_holds_under_random_arrivals() {
    // Random arrival patterns: lanes may run dry. The exact invariant
    // is pairwise — any two lanes that stayed backlogged over the whole
    // window split cost by weight within one quantum each.
    cases(30, |rng| {
        let n = rng.range(2, 4);
        let cap = rng.range(3, 6);
        let (mut lanes, idx, weights) = tenant_lanes(rng, n, cap);
        let quanta = rng.range(100, 300);
        let mut served = vec![0u64; n];
        let mut cmax = vec![0u64; n];
        let mut always_backlogged = vec![true; n];
        for _ in 0..quanta {
            for (k, &l) in idx.iter().enumerate() {
                // Bursty arrivals: each lane refills only sometimes.
                if rng.chance(0.7) {
                    let _ = lanes.try_push(l, 0);
                }
                if lanes.len_of(l) == 0 {
                    always_backlogged[k] = false;
                }
            }
            let Some(lane) = lanes.pick() else { continue };
            lanes.drain(lane, 1);
            let cost = rng.range(1, 4) as u64;
            lanes.charge(lane, cost);
            let k = idx.iter().position(|&x| x == lane).unwrap();
            served[k] += cost;
            cmax[k] = cmax[k].max(cost);
        }
        for i in 0..n {
            for j in (i + 1)..n {
                if !(always_backlogged[i] && always_backlogged[j]) {
                    continue;
                }
                let norm_i = served[i] as f64 / weights[i] as f64;
                let norm_j = served[j] as f64 / weights[j] as f64;
                let bound =
                    cmax[i] as f64 / weights[i] as f64 + cmax[j] as f64 / weights[j] as f64 + 1.0;
                assert!(
                    (norm_i - norm_j).abs() <= bound,
                    "backlogged lanes {i},{j}: normalized service {norm_i:.2} vs {norm_j:.2} (bound {bound:.2})"
                );
            }
        }
    });
}

#[test]
fn prop_idle_lane_cannot_bank_credit_across_reactivation() {
    cases(25, |rng| {
        let (mut lanes, idx, weights) = tenant_lanes(rng, 2, 4);
        let idle_quanta = rng.range(20, 100);
        // Phase A: lane 0 idle, lane 1 alone consumes `idle_quanta`.
        for _ in 0..idle_quanta {
            let _ = lanes.try_push(idx[1], 0);
            let lane = lanes.pick().expect("lane 1 is backlogged");
            assert_eq!(lane, idx[1], "an idle lane must never be picked");
            lanes.drain(lane, 1);
            lanes.charge(lane, 1);
        }
        // Phase B: lane 0 wakes up; measured from here, shares must obey
        // the same one-window bound — no retroactive credit for phase A.
        let quanta = rng.range(100, 300);
        let mut served = [0u64; 2];
        for _ in 0..quanta {
            for &l in &idx {
                let _ = lanes.try_push(l, 0);
            }
            let lane = lanes.pick().expect("both lanes are backlogged");
            lanes.drain(lane, 1);
            lanes.charge(lane, 1);
            served[idx.iter().position(|&x| x == lane).unwrap()] += 1;
        }
        let total = (served[0] + served[1]) as f64;
        let wsum = (weights[0] + weights[1]) as f64;
        for k in 0..2 {
            let expected = total * weights[k] as f64 / wsum;
            let bound = share_bound(k, &weights, &[1, 1]) + 1.0;
            assert!(
                (served[k] as f64 - expected).abs() <= bound,
                "post-reactivation share must be fair: lane {k} served {} vs {expected:.2} (idle {idle_quanta})",
                served[k]
            );
        }
    });
}

#[test]
fn prop_lane_scheduling_is_deterministic_replay() {
    // Same seed, same arrivals, same costs → bit-identical pick
    // sequence. This is the property the CI fairness smoke leans on
    // (identical lane shares at any solver thread count).
    cases(10, |rng| {
        let seed = rng.next_u64();
        let run = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            let (mut lanes, idx, _) = tenant_lanes(&mut rng, 3, 4);
            let mut picks = Vec::new();
            for _ in 0..200 {
                for &l in &idx {
                    if rng.chance(0.8) {
                        let _ = lanes.try_push(l, 0);
                    }
                }
                if let Some(lane) = lanes.pick() {
                    lanes.drain(lane, 1);
                    lanes.charge(lane, rng.range(1, 3) as u64);
                    picks.push(lane);
                }
            }
            picks
        };
        assert_eq!(run(seed), run(seed), "lane scheduling must replay identically");
    });
}

#[test]
fn prop_queue_caps_bound_every_lane() {
    cases(15, |rng| {
        let n = rng.range(2, 4);
        let caps: Vec<usize> = (0..n).map(|_| rng.range(0, 5)).collect();
        let specs: Vec<LaneSpec> =
            caps.iter().enumerate().map(|(i, &c)| LaneSpec::new(format!("t{i}"), 1, c)).collect();
        let mut lanes: LaneSet<u32> = LaneSet::new(specs);
        let idx: Vec<usize> = (0..n).map(|i| lanes.resolve(Some(format!("t{i}").as_str()))).collect();
        for _ in 0..50 {
            let k = rng.range(0, n - 1);
            let before = lanes.len_of(idx[k]);
            let accepted = lanes.try_push(idx[k], 7).is_ok();
            assert_eq!(accepted, before < caps[k], "push must succeed iff the lane had room");
            assert!(lanes.len_of(idx[k]) <= caps[k], "lane {k} exceeded its cap {}", caps[k]);
        }
        let total_cap: usize = caps.iter().sum();
        assert!(lanes.total_len() <= total_cap);
        // Zero-cap lanes are never backlogged, so never picked.
        while let Some(lane) = lanes.pick() {
            let k = idx.iter().position(|&x| x == lane).unwrap();
            assert!(caps[k] > 0, "a zero-capacity lane must never be scheduled");
            lanes.drain(lane, 1);
            lanes.charge(lane, 1);
        }
    });
}

// ------------------------------------------------ scheduler regressions

fn small_graph() -> Graph {
    experiments::vit_mlp_stage(16, 24, 48)
}

fn cfg(soc: &str, strategy: Strategy) -> DeployConfig {
    DeployConfig::preset(soc, strategy).unwrap()
}

fn small_service() -> Arc<PlanService> {
    Arc::new(PlanService::new(ServeOptions {
        cache_capacity: 8,
        cache_shards: 2,
        workers: 1,
        ..ServeOptions::default()
    }))
}

/// The pre-lane FIFO scenario, scripted: the exact `BatchOutcome`
/// sequence and `batch.*` counters the single-queue scheduler produced
/// must be reproduced bit-identically by the degenerate single-default-
/// lane configuration.
#[test]
fn single_default_lane_reproduces_fifo_outcomes_and_counters() {
    let sched = BatchScheduler::new(
        small_service(),
        BatchOptions { batch_window: Duration::ZERO, queue_capacity: 8, ..BatchOptions::default() },
    );
    // Exactly one lane, named `default`, inheriting the queue capacity.
    assert_eq!(sched.lane_specs().len(), 1);
    assert_eq!(sched.lane_specs()[0].name, DEFAULT_LANE);
    assert_eq!(sched.lane_specs()[0].capacity, 8);
    assert_eq!(sched.lane_specs()[0].weight, 1);

    // 1. Cold request: batched, served.
    let a = sched.deploy("a", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    assert!(matches!(a, BatchOutcome::Served(_)));
    // 2. Warm repeat: served via the fast path, not batched.
    let b = sched.deploy("b", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    let b = b.served().unwrap();
    assert!(b.cached && b.sim_cached);
    // 3. Pre-expired deadline: timed out before enqueue.
    let c = sched
        .deploy_with_deadline("c", small_graph(), cfg("cluster-only", Strategy::Ftl), Some(Duration::ZERO))
        .unwrap();
    assert!(matches!(c, BatchOutcome::TimedOut));

    // The FIFO scheduler's exact counters for this script.
    let stats = sched.stats();
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.batched_requests, 1);
    assert_eq!(stats.max_batch_size, 1);
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.timeouts, 1);
    assert_eq!(stats.queue_depth, 0);
    assert_eq!(stats.queue_capacity, 8);
    assert_eq!(sched.service().stats().solves, 1);
    assert_eq!(sched.service().stats().sims, 1);

    // The per-lane breakdown degenerates to the global counters.
    assert_eq!(stats.lanes.len(), 1);
    let lane = &stats.lanes[0];
    assert_eq!(
        (lane.batches, lane.batched_requests, lane.shed, lane.timeouts, lane.served),
        (stats.batches, stats.batched_requests, stats.shed, stats.timeouts, 1)
    );
    assert_eq!(lane.cold_work, 2, "one solve + one sim of cold work");

    // 4. Zero-capacity queue sheds under both policies (the FIFO
    // contract, per lane now).
    for policy in [AdmissionPolicy::Shed, AdmissionPolicy::Block] {
        let gate = BatchScheduler::new(
            small_service(),
            BatchOptions { queue_capacity: 0, policy, ..BatchOptions::default() },
        );
        let z = gate.deploy("z", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
        assert!(matches!(z, BatchOutcome::Shed));
        assert_eq!(gate.stats().shed, 1);
        assert_eq!(gate.stats().lanes[0].shed, 1);
    }
}

#[test]
fn zero_capacity_lane_sheds_everything_without_touching_other_lanes() {
    let sched = BatchScheduler::new(
        small_service(),
        BatchOptions {
            batch_window: Duration::ZERO,
            lanes: vec![LaneSpec::new("walled-off", 5, 0)],
            ..BatchOptions::default()
        },
    );
    for i in 0..3 {
        let c = cfg("cluster-only", Strategy::Ftl);
        let z = sched.deploy_in_lane(&format!("z{i}"), small_graph(), c, Some("walled-off"), None).unwrap();
        assert!(matches!(z, BatchOutcome::Shed), "a zero-capacity lane must shed everything");
    }
    // The default lane is unaffected — and the sheds are attributed to
    // the zero-capacity lane, not smeared over the victims.
    let ok = sched.deploy("ok", small_graph(), cfg("cluster-only", Strategy::Ftl)).unwrap();
    assert!(matches!(ok, BatchOutcome::Served(_)));
    let stats = sched.stats();
    let by = |name: &str| stats.lanes.iter().find(|l| l.name == name).unwrap();
    assert_eq!(by("walled-off").shed, 3);
    assert_eq!(by(DEFAULT_LANE).shed, 0);
    assert_eq!(stats.shed, 3, "global shed must be the lane sum");
    assert_eq!(sched.service().stats().solves, 1, "shed requests must never reach the solver");
}

#[test]
fn deadline_parked_on_full_non_default_lane_times_out_not_starves() {
    // Lane `tiny` has capacity 1 and Block policy; a long batch window
    // keeps the occupant parked in the queue, so the second submitter
    // blocks on a full lane — and must be released by its own deadline,
    // long before the window drains the lane.
    let sched = Arc::new(BatchScheduler::new(
        small_service(),
        BatchOptions {
            batch_window: Duration::from_millis(2_000),
            policy: AdmissionPolicy::Block,
            lanes: vec![LaneSpec::new("tiny", 2, 1)],
            ..BatchOptions::default()
        },
    ));
    let occupant = {
        let sched = sched.clone();
        std::thread::spawn(move || {
            sched.deploy_in_lane("occupant", small_graph(), cfg("cluster-only", Strategy::Ftl), Some("tiny"), None)
        })
    };
    let start = std::time::Instant::now();
    while sched.stats().queue_depth == 0
        && sched.stats().batched_requests == 0
        && start.elapsed() < Duration::from_secs(10)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    let t = std::time::Instant::now();
    let outcome = sched
        .deploy_in_lane(
            "deadlined",
            small_graph(),
            cfg("cluster-only", Strategy::Ftl),
            Some("tiny"),
            Some(Duration::from_millis(50)),
        )
        .unwrap();
    assert!(matches!(outcome, BatchOutcome::TimedOut), "a parked submitter must honour its deadline");
    assert!(t.elapsed() < Duration::from_millis(1_900), "the timeout must beat the batch window");
    let stats = sched.stats();
    let tiny = stats.lanes.iter().find(|l| l.name == "tiny").unwrap();
    assert!(tiny.timeouts >= 1, "the timeout must be charged to the lane that parked it");
    assert_eq!(stats.lanes.iter().find(|l| l.name == DEFAULT_LANE).unwrap().timeouts, 0);
    let first = occupant.join().unwrap().unwrap();
    assert!(matches!(first, BatchOutcome::Served(_)), "the occupant must still be served");
}

#[test]
fn deadline_expiring_while_queued_in_lane_resolves_timeout_at_dispatch() {
    // The request is *admitted* into a non-default lane, then its
    // deadline lapses while it waits out the batch window. Dispatch
    // must resolve it TIMEOUT (and charge the lane), not solve it late
    // and not strand the submitter.
    let sched = BatchScheduler::new(
        small_service(),
        BatchOptions {
            batch_window: Duration::from_millis(400),
            lanes: vec![LaneSpec::new("slow", 1, 8)],
            ..BatchOptions::default()
        },
    );
    let outcome = sched
        .deploy_in_lane(
            "expires-in-queue",
            small_graph(),
            cfg("cluster-only", Strategy::Ftl),
            Some("slow"),
            Some(Duration::from_millis(30)),
        )
        .unwrap();
    assert!(matches!(outcome, BatchOutcome::TimedOut), "a queued request must time out at dispatch");
    let stats = sched.stats();
    let slow = stats.lanes.iter().find(|l| l.name == "slow").unwrap();
    assert_eq!(slow.timeouts, 1);
    assert_eq!(slow.batched_requests, 1, "the request was admitted and dispatched, then expired");
    assert_eq!(sched.service().stats().solves, 0, "an expired request must not consume solver time");
    assert_eq!(stats.timeouts, 1, "global timeouts must be the lane sum");
}

#[test]
fn lane_counter_sums_equal_global_batch_counters_under_mixed_traffic() {
    // Mixed traffic over three lanes — served, shed (zero-cap lane) and
    // timed out (zero deadline) — then the invariant the per-lane split
    // was built around: every `batch.*` total equals its lane sum.
    let sched = BatchScheduler::new(
        small_service(),
        BatchOptions {
            batch_window: Duration::ZERO,
            lanes: vec![LaneSpec::new("gold", 3, 16), LaneSpec::new("off", 1, 0)],
            ..BatchOptions::default()
        },
    );
    let g = small_graph();
    let served = sched
        .deploy_in_lane("gold-req", g.clone(), cfg("cluster-only", Strategy::Ftl), Some("gold"), None)
        .unwrap();
    assert!(matches!(served, BatchOutcome::Served(_)));
    let shed = sched
        .deploy_in_lane("off-req", g.clone(), cfg("cluster-only", Strategy::Ftl), Some("off"), None)
        .unwrap();
    assert!(matches!(shed, BatchOutcome::Shed));
    let late = sched
        .deploy_in_lane("late", g.clone(), cfg("siracusa", Strategy::Ftl), None, Some(Duration::ZERO))
        .unwrap();
    assert!(matches!(late, BatchOutcome::TimedOut));
    let cold_default = sched.deploy("default-req", g, cfg("siracusa", Strategy::Ftl)).unwrap();
    assert!(matches!(cold_default, BatchOutcome::Served(_)));

    let stats = sched.stats();
    assert_eq!(stats.lanes.iter().map(|l| l.batches).sum::<u64>(), stats.batches);
    assert_eq!(stats.lanes.iter().map(|l| l.batched_requests).sum::<u64>(), stats.batched_requests);
    assert_eq!(stats.lanes.iter().map(|l| l.shed).sum::<u64>(), stats.shed);
    assert_eq!(stats.lanes.iter().map(|l| l.timeouts).sum::<u64>(), stats.timeouts);
    assert_eq!(stats.lanes.iter().map(|l| l.queue_depth).sum::<usize>(), stats.queue_depth);
    assert_eq!(stats.lanes.iter().map(|l| l.capacity).sum::<usize>(), stats.queue_capacity);
    assert_eq!(stats.lanes.iter().map(|l| l.max_batch_size).max().unwrap(), stats.max_batch_size);
    // And the JSON snapshot exposes the same split under batch.lanes.*.
    let j = sched.stats_json();
    let lanes_json = j.get("batch").unwrap().get("lanes").unwrap();
    assert_eq!(lanes_json.get("off").unwrap().get("shed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(lanes_json.get("gold").unwrap().get("shed").unwrap().as_usize().unwrap(), 0);
    let global_shed = j.get("batch").unwrap().get("shed").unwrap().as_usize().unwrap();
    assert_eq!(global_shed, 1);

    // Specific satellite claim: one aggressive tenant's sheds are
    // distinguishable from its victims' counters.
    let gold = stats.lanes.iter().find(|l| l.name == "gold").unwrap();
    let off = stats.lanes.iter().find(|l| l.name == "off").unwrap();
    assert_eq!((gold.shed, off.shed), (0, 1));
    assert!(gold.cold_work >= 2 && off.cold_work == 0);
}
