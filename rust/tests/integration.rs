//! Integration tests: the whole deployment pipeline across workloads,
//! SoCs, strategies and buffering modes.

use ftl::config::DeployConfig;
use ftl::coordinator::{experiments, Deployer};
use ftl::ir::builder::{deep_mlp, vit_mlp, vit_mlp_block, vit_mlp_preset};
use ftl::ir::{graph_from_json, graph_to_json, DType};
use ftl::memory::Level;
use ftl::runtime::NativeBackend;
use ftl::tiling::{FusionPolicy, Strategy};

fn all_configs() -> Vec<DeployConfig> {
    let mut out = Vec::new();
    for soc in ["siracusa", "cluster-only"] {
        for strategy in [Strategy::LayerPerLayer, Strategy::Ftl] {
            for dbuf in [false, true] {
                let mut cfg = DeployConfig::preset(soc, strategy).unwrap();
                cfg.double_buffer = dbuf;
                out.push(cfg);
            }
        }
    }
    out
}

#[test]
fn every_workload_deploys_on_every_config() {
    let workloads = vec![
        ("stage", experiments::vit_mlp_stage(197, 768, 3072)),
        ("mlp", vit_mlp(96, 128, 512, DType::Int8)),
        ("block", vit_mlp_block(64, 96, 384, DType::Int8)),
        ("deep", deep_mlp(64, 256, 3, DType::Int8)),
    ];
    for (name, graph) in workloads {
        for cfg in all_configs() {
            let label = format!("{name}/{}/{}/dbuf={}", cfg.soc.name, cfg.strategy, cfg.double_buffer);
            let (plan, report) = Deployer::new(graph.clone(), cfg.clone())
                .with_workload_name(name)
                .deploy()
                .unwrap_or_else(|e| panic!("{label}: {e:#}"));
            assert!(report.sim.total_cycles > 0, "{label}: zero cycles");
            assert!(plan.solution.peak_l1() <= cfg.soc.mem.capacity(Level::L1), "{label}: L1 overflow");
            assert_eq!(report.phases, plan.groups.len(), "{label}: phase count mismatch");
        }
    }
}

#[test]
fn ftl_never_slower_and_never_moves_more_data() {
    for preset in ["siracusa", "cluster-only"] {
        for (seq, d, h) in [(197, 768, 3072), (128, 256, 1024), (32, 64, 128)] {
            let run = |strategy| {
                let graph = experiments::vit_mlp_stage(seq, d, h);
                let cfg = DeployConfig::preset(preset, strategy).unwrap();
                Deployer::new(graph, cfg).deploy().unwrap().1
            };
            let base = run(Strategy::LayerPerLayer);
            let ftl = run(Strategy::Ftl);
            assert!(
                ftl.sim.total_cycles <= base.sim.total_cycles,
                "{preset} {seq}x{d}x{h}: FTL slower ({} vs {})",
                ftl.sim.total_cycles,
                base.sim.total_cycles
            );
            assert!(
                ftl.sim.dma.total_bytes() <= base.sim.dma.total_bytes(),
                "{preset} {seq}x{d}x{h}: FTL moved more data"
            );
        }
    }
}

#[test]
fn all_vit_presets_deploy() {
    for preset in ["vit-tiny", "vit-small", "vit-base", "vit-large"] {
        let graph = vit_mlp_preset(preset).unwrap();
        let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
        let (_, report) = Deployer::new(graph, cfg).with_workload_name(preset).deploy().unwrap();
        assert!(report.sim.total_cycles > 0);
    }
}

#[test]
fn network_json_roundtrip_deploys_identically() {
    let graph = experiments::vit_mlp_stage(197, 768, 3072);
    let text = graph_to_json(&graph).unwrap();
    let graph2 = graph_from_json(&text).unwrap();
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    let r1 = Deployer::new(graph, cfg.clone()).deploy().unwrap().1;
    let r2 = Deployer::new(graph2, cfg).deploy().unwrap().1;
    assert_eq!(r1.sim.total_cycles, r2.sim.total_cycles);
    assert_eq!(r1.dma_bytes, r2.dma_bytes);
}

#[test]
fn numerics_hold_across_all_strategies_and_socs() {
    let graph = vit_mlp(48, 64, 160, DType::F32);
    for cfg in all_configs() {
        let label = format!("{}/{}/dbuf={}", cfg.soc.name, cfg.strategy, cfg.double_buffer);
        let worst = Deployer::new(graph.clone(), cfg).validate_numerics(NativeBackend, 11).unwrap();
        assert!(worst < 1e-3, "{label}: deviation {worst}");
    }
}

#[test]
fn fusion_policy_effects() {
    let graph = deep_mlp(64, 256, 4, DType::Int8);
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    let solo = Deployer::new(graph.clone(), cfg.clone())
        .with_policy(FusionPolicy { max_len: 1, elementwise_only: true })
        .deploy()
        .unwrap()
        .1;
    let fused = Deployer::new(graph, cfg)
        .with_policy(FusionPolicy { max_len: 4, elementwise_only: true })
        .deploy()
        .unwrap()
        .1;
    assert!(fused.phases < solo.phases);
    assert!(fused.sim.dma.total_bytes() <= solo.sim.dma.total_bytes());
}

#[test]
fn report_json_is_parseable() {
    let graph = experiments::vit_mlp_stage(64, 96, 256);
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    let soc = cfg.soc.clone();
    let (_, report) = Deployer::new(graph, cfg).deploy().unwrap();
    let j = report.to_json(&soc);
    let parsed = ftl::util::json::parse(&j.pretty()).unwrap();
    assert!(parsed.get("sim").unwrap().get("total_cycles").unwrap().as_usize().unwrap() > 0);
}

#[test]
fn experiments_full_mlp_extension() {
    let (base, ftl_c, red) = experiments::full_mlp(197, 768, 3072, "siracusa").unwrap();
    assert!(ftl_c < base);
    assert!(red > 0.0);
}

#[test]
fn paper_headline_numbers_within_tolerance() {
    // The reproduction gate, asserted at integration level too: the
    // calibrated SoC reproduces the paper's Fig. 3 within ±6 pp and the
    // DMA-volume claim within ±12 pp (see EXPERIMENTS.md §Calibration).
    let rows = experiments::fig3(197, 768, 3072, false).unwrap();
    let get = |config: &str| {
        rows.iter().find(|r| r.config == config && r.strategy == "ftl").unwrap().reduction_pct
    };
    let cluster = get("cluster");
    let npu = get("cluster+npu");
    assert!((cluster - 28.8).abs() < 6.0, "cluster: {cluster:.1}% vs paper 28.8%");
    assert!((npu - 60.1).abs() < 6.0, "npu: {npu:.1}% vs paper 60.1%");
    let dma = experiments::dma_reduction(197, 768, 3072, "cluster-only").unwrap();
    assert!((dma.byte_reduction_pct - 47.1).abs() < 12.0, "dma: {:.1}%", dma.byte_reduction_pct);
}
