//! Wire-protocol integration tests (PR 7): a generated round-trip
//! corpus over [`ftl::serve::proto::Frame`], and over-the-wire checks
//! against a live front door — malformed and oversized lines answered
//! on the offending id without disconnecting, out-of-order interleaving
//! of id'd responses on one connection, and strict v0 compatibility
//! (legacy shapes, strict request order, no v1 fields).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use ftl::serve::proto::{DeployCommand, Frame, Request, Version, DEFAULT_DUMP_COUNT, MAX_FRAME_BYTES};
use ftl::serve::{
    AdmissionPolicy, BatchOptions, BatchScheduler, Frontend, FrontendHandle, FrontendOptions, PlanService,
    ServeOptions, TraceOptions,
};
use ftl::util::json::Json;

fn frontend() -> FrontendHandle {
    let service = Arc::new(PlanService::new(ServeOptions {
        cache_capacity: 32,
        sim_cache_capacity: 64,
        cache_shards: 2,
        workers: 1,
        ..ServeOptions::default()
    }));
    let scheduler = Arc::new(BatchScheduler::new(
        service,
        BatchOptions {
            queue_capacity: 64,
            batch_window: Duration::ZERO,
            policy: AdmissionPolicy::Block,
            trace: TraceOptions::disabled(),
            ..BatchOptions::default()
        },
    ));
    Frontend::new(scheduler, FrontendOptions::default())
        .serve(TcpListener::bind("127.0.0.1:0").expect("bind test port"))
        .expect("start front door")
}

fn connect(door: &FrontendHandle) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(door.addr()).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    (stream, reader)
}

fn read_json(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    let n = reader.read_line(&mut line).expect("read reply");
    assert!(n > 0, "server closed the connection");
    ftl::util::json::parse(line.trim()).expect("parse reply")
}

/// Deterministic xorshift so the corpus is reproducible run to run.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    fn pick<'a>(&mut self, xs: &[&'a str]) -> &'a str {
        xs[(self.next() % xs.len() as u64) as usize]
    }
}

#[test]
fn frame_render_parse_round_trips_over_a_generated_corpus() {
    let mut rng = Rng(0x5eed_cafe);
    let workloads = ["vit-tiny-stage", "stage-16x24x48", "mlp", "w_1"];
    let socs = ["cluster-only", "siracusa"];
    let strategies = ["ftl", "layer-per-layer", "flat"];
    let lanes = ["gold", "bulk", "free"];
    for _ in 0..500 {
        let request = match rng.next() % 8 {
            0 => Request::Stats,
            1 => Request::Ping,
            2 => Request::Metrics,
            3 => Request::Trace { n: (rng.next() % 64) as usize },
            4 => Request::Slow { n: (rng.next() % 64) as usize },
            _ => Request::Deploy(DeployCommand {
                workload: rng.pick(&workloads).to_string(),
                soc: rng.pick(&socs).to_string(),
                strategy: rng.pick(&strategies).to_string(),
                deadline_ms: match rng.next() % 3 {
                    0 => None,
                    _ => Some(rng.next() % 100_000),
                },
                lane: match rng.next() % 3 {
                    0 => None,
                    _ => Some(rng.pick(&lanes).to_string()),
                },
            }),
        };
        let (version, id) = if rng.next() % 2 == 0 { (Version::V1, Some(rng.next())) } else { (Version::V0, None) };
        let frame = Frame { version, id, request };

        let line = frame.render();
        assert!(line.len() <= MAX_FRAME_BYTES, "generated frames stay under the cap");
        let back = Frame::parse(&line).unwrap_or_else(|e| panic!("'{line}' must re-parse: {e}"));
        assert_eq!(back, frame, "round trip changed '{line}'");
        assert_eq!(back.render(), line, "render must be canonical for '{line}'");
    }
}

#[test]
fn parse_normalizes_whitespace_and_bare_dump_counts() {
    let f = Frame::parse("  FTL1   7   STATS  ").unwrap();
    assert_eq!(f.render(), "FTL1 7 STATS");
    let f = Frame::parse("TRACE").unwrap();
    assert_eq!(f.render(), format!("TRACE {DEFAULT_DUMP_COUNT}"));
    assert_eq!(Frame::parse(&f.render()).unwrap(), f);
}

#[test]
fn malformed_and_oversized_lines_never_disconnect() {
    let door = frontend();
    let (mut stream, mut reader) = connect(&door);

    // Malformed v1 command: the error is delivered on the frame's id.
    stream.write_all(b"FTL1 9 NOPE nope\n").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 9);
    assert_eq!(j.get("event").unwrap().as_str().unwrap(), "error");
    assert!(j.get("error").unwrap().as_str().unwrap().contains("bad request"));

    // Malformed v0 line: legacy error object, no v1 fields.
    stream.write_all(b"NOPE\n").unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap().contains("bad request"));
    assert!(j.get_opt("v").is_none() && j.get_opt("id").is_none());

    // One line far past MAX_FRAME_BYTES, then a PING on the same
    // connection: the oversized line is rejected (id recovered from its
    // prefix) and discarded, and the connection must survive.
    let mut big = String::from("FTL1 11 DEPLOY ");
    big.push_str(&"x".repeat(MAX_FRAME_BYTES + 1024));
    big.push('\n');
    stream.write_all(big.as_bytes()).unwrap();
    stream.write_all(b"FTL1 12 PING\n").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 11);
    assert_eq!(j.get("event").unwrap().as_str().unwrap(), "error");
    assert!(j.get("error").unwrap().as_str().unwrap().contains("oversized"));
    let j = read_json(&mut reader);
    assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 12);
    assert_eq!(j.get("event").unwrap().as_str().unwrap(), "done");
    assert!(j.get("pong").unwrap().as_bool().unwrap());

    assert!(door.counters().protocol_errors.get() >= 3, "each bad line counts as a protocol error");
    door.join();
}

#[test]
fn responses_interleave_out_of_order_with_their_own_ids() {
    let door = frontend();
    let (mut stream, mut reader) = connect(&door);

    // Warm one fingerprint first so id 3 below has a fast path.
    stream.write_all(b"FTL1 1 DEPLOY stage-16x24x48 cluster-only ftl\n").unwrap();
    loop {
        let j = read_json(&mut reader);
        assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 1);
        if j.get("event").unwrap().as_str().unwrap() == "done" {
            break;
        }
    }

    // One cold + one warm, pipelined on the same connection. The warm
    // reply (id 3) must land before the cold solve (id 2) finishes, and
    // the cold stream keeps plan -> sim* -> done on its own id.
    stream
        .write_all(b"FTL1 2 DEPLOY stage-32x24x48 cluster-only ftl\nFTL1 3 DEPLOY stage-16x24x48 cluster-only ftl\n")
        .unwrap();
    let mut terminals: Vec<u64> = Vec::new();
    let mut cold_kinds: Vec<String> = Vec::new();
    while terminals.len() < 2 {
        let j = read_json(&mut reader);
        let id = j.get("id").unwrap().as_u64().unwrap();
        let event = j.get("event").unwrap().as_str().unwrap().to_string();
        if event == "done" {
            terminals.push(id);
        }
        if id == 2 {
            cold_kinds.push(event);
        } else {
            assert_eq!(id, 3);
            assert_eq!(event, "done", "the warm id must not stream partials");
        }
    }
    assert_eq!(terminals, [3, 2], "the warm reply must overtake the cold solve");
    assert_eq!(cold_kinds.first().map(String::as_str), Some("plan"));
    assert_eq!(cold_kinds.last().map(String::as_str), Some("done"));
    assert!(cold_kinds.iter().filter(|k| *k == "sim").count() >= 1, "cold deploys stream per-phase sim events");
    door.join();
}

#[test]
fn v0_lines_are_served_strictly_in_order_without_v1_fields() {
    let door = frontend();
    let (mut stream, mut reader) = connect(&door);
    stream
        .write_all(
            b"PING\nDEPLOY stage-16x24x48 cluster-only ftl\nDEPLOY stage-16x24x48 cluster-only ftl\nSTATS\n",
        )
        .unwrap();
    let pong = read_json(&mut reader);
    assert!(pong.get("pong").unwrap().as_bool().unwrap(), "PING must be answered first");
    for _ in 0..2 {
        let dep = read_json(&mut reader);
        assert_eq!(dep.get("outcome").unwrap().as_str().unwrap(), "OK", "deploys answer in request order");
    }
    let stats = read_json(&mut reader);
    assert!(stats.get_opt("batch").is_some(), "STATS must be answered last");
    for j in [&pong, &stats] {
        assert!(
            j.get_opt("v").is_none() && j.get_opt("id").is_none() && j.get_opt("event").is_none(),
            "v0 replies keep their legacy shape"
        );
    }
    door.join();
}
