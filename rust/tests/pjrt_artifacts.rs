//! PJRT artifact tests — gated on `artifacts/manifest.json` existing
//! (built by `make artifacts`). Without artifacts they are skipped with a
//! notice, so `cargo test` stays green on a fresh checkout; `make test`
//! builds artifacts first and runs them for real.
//!
//! Tests that *execute* artifacts are additionally gated on the `xla`
//! feature: the default offline build compiles a stub `PjrtBackend`
//! whose `run()` errors and whose `exec()` falls back to the native
//! reference (see `rust/src/runtime/pjrt.rs`), so running them against
//! the stub would fail (or pass vacuously) even with artifacts present.

use std::path::Path;

use ftl::runtime::PjrtBackend;

#[cfg(feature = "xla")]
use ftl::config::DeployConfig;
#[cfg(feature = "xla")]
use ftl::coordinator::{experiments, Deployer};
#[cfg(feature = "xla")]
use ftl::runtime::{reference, TileExecutor};
#[cfg(feature = "xla")]
use ftl::tiling::Strategy;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping PJRT test: run `make artifacts` first");
        None
    }
}

#[test]
fn manifest_loads_and_lists_tiles() {
    let Some(dir) = artifacts() else { return };
    let backend = PjrtBackend::new(dir).unwrap();
    let m = backend.manifest();
    assert!(!m.entries.is_empty());
    assert!(m.entries.keys().any(|k| k.starts_with("gemm")), "manifest must contain GEMM tiles");
    for e in m.entries.values() {
        assert!(m.dir.join(&e.file).exists(), "artifact file {} missing", e.file);
    }
}

#[cfg(feature = "xla")]
#[test]
fn single_tile_artifact_matches_native() {
    let Some(dir) = artifacts() else { return };
    let mut backend = PjrtBackend::new(dir).unwrap();
    // Pick any gemm entry and run it against the native reference.
    let entry = backend
        .manifest()
        .entries
        .values()
        .find(|e| e.name.starts_with("gemm_b_"))
        .expect("a biased gemm tile exists")
        .clone();
    let inputs: Vec<ftl::runtime::HostTensor> = entry
        .in_shapes
        .iter()
        .enumerate()
        .map(|(i, s)| ftl::runtime::HostTensor::random(s, 100 + i as u64))
        .collect();
    let refs: Vec<&ftl::runtime::HostTensor> = inputs.iter().collect();
    let got = backend.run(&entry.name, &refs).unwrap();
    let want = reference::gemm(&inputs[0], &inputs[1], Some(&inputs[2]), false).unwrap();
    let diff = got.max_abs_diff(&want);
    assert!(diff < 1e-3, "artifact {} deviates from native by {diff}", entry.name);
}

#[cfg(feature = "xla")]
#[test]
fn ftl_tiled_pjrt_execution_matches_oracle() {
    let Some(dir) = artifacts() else { return };
    let graph = experiments::vit_mlp_stage(197, 768, 3072);
    let cfg = DeployConfig::preset("siracusa", Strategy::Ftl).unwrap();
    let dep = Deployer::new(graph, cfg);
    let plan = dep.plan().unwrap();
    let bindings = reference::random_bindings(dep.graph(), 77);
    let oracle = reference::run_graph(dep.graph(), &bindings).unwrap();
    let mut exec = TileExecutor::new(PjrtBackend::new(dir).unwrap());
    let env = exec.run(dep.graph(), &plan.solution, &bindings).unwrap();
    let out = dep.graph().outputs()[0];
    let diff = env[&out].max_abs_diff(&oracle[&out]);
    assert!(diff < 1e-3, "PJRT tiled execution off by {diff}");
    assert!(exec.backend().invocations > 0, "PJRT backend must actually serve kernels");
}

#[cfg(feature = "xla")]
#[test]
fn baseline_tiled_pjrt_execution_matches_oracle() {
    let Some(dir) = artifacts() else { return };
    let graph = experiments::vit_mlp_stage(197, 768, 3072);
    let cfg = DeployConfig::preset("cluster-only", Strategy::LayerPerLayer).unwrap();
    let dep = Deployer::new(graph, cfg);
    let plan = dep.plan().unwrap();
    let bindings = reference::random_bindings(dep.graph(), 78);
    let oracle = reference::run_graph(dep.graph(), &bindings).unwrap();
    let mut exec = TileExecutor::new(PjrtBackend::new(dir).unwrap());
    let env = exec.run(dep.graph(), &plan.solution, &bindings).unwrap();
    let out = dep.graph().outputs()[0];
    let diff = env[&out].max_abs_diff(&oracle[&out]);
    assert!(diff < 1e-3, "baseline PJRT execution off by {diff}");
}

#[cfg(feature = "xla")]
#[test]
fn whole_stage_artifacts_agree() {
    let Some(dir) = artifacts() else { return };
    let mut backend = PjrtBackend::new(dir).unwrap();
    let (s, d, h) = (197, 768, 3072);
    let x = ftl::runtime::HostTensor::random(&[s, d], 1);
    let w = ftl::runtime::HostTensor::random(&[d, h], 2);
    let b = ftl::runtime::HostTensor::random(&[h], 3);
    let refr = backend.run(&format!("stage_ref_{s}x{d}x{h}"), &[&x, &w, &b]).unwrap();
    let base = backend.run(&format!("stage_baseline_{s}x{d}x{h}"), &[&x, &w, &b]).unwrap();
    let fused = backend.run(&format!("stage_ftl_{s}x{d}x{h}"), &[&x, &w, &b]).unwrap();
    assert!(base.max_abs_diff(&refr) < 1e-2);
    assert!(fused.max_abs_diff(&refr) < 1e-2);
    assert!(fused.max_abs_diff(&base) < 1e-2);
}

#[test]
fn wrong_shape_rejected_before_ffi() {
    let Some(dir) = artifacts() else { return };
    let mut backend = PjrtBackend::new(dir).unwrap();
    let entry = backend.manifest().entries.values().next().unwrap().clone();
    let bad = ftl::runtime::HostTensor::random(&[1, 1], 0);
    let refs: Vec<&ftl::runtime::HostTensor> = entry.in_shapes.iter().map(|_| &bad).collect();
    assert!(backend.run(&entry.name, &refs).is_err());
}
