//! Latency-histogram and tracing properties for the serve stack.
//!
//! Three layers, mirroring the observability docs in `ftl::serve`:
//!
//! * **Histogram properties** — seeded random value sets across the full
//!   magnitude range assert the documented quantile bound (the reported
//!   bucket midpoint is within 1/8 relative error of the empirical
//!   same-rank sample) and that merged histograms answer quantiles
//!   bounded by their inputs' answers.
//! * **Wave invariants** — the shared `serve::wave::mixed_lane_wave`
//!   driver (seeded, multi-threaded, mixed warm/cold traffic across two
//!   lanes) must leave the tracer with per-lane histograms that merge
//!   bucket-for-bucket into the independently recorded scheduler-wide
//!   histogram, at any `FTL_SOLVER_THREADS`.
//! * **Protocol regressions** — `METRICS` round-trips the strict
//!   exposition parser with per-lane×temp labelled series, `STATS`
//!   carries the `server` identity block and `latency` summaries, and
//!   `TRACE`/`SLOW` dump JSON-lines spans with monotone stage offsets.

use std::sync::Arc;
use std::time::Duration;

use ftl::config::DeployConfig;
use ftl::coordinator::experiments;
use ftl::metrics::{expo, Histogram};
use ftl::serve::wave::mixed_lane_wave;
use ftl::serve::{handle_command, BatchOptions, BatchScheduler, PlanService, ServeOptions, TraceOptions};
use ftl::tiling::Strategy;
use ftl::util::json;
use ftl::util::prop::{cases, Rng};

// ------------------------------------------------------ histogram properties

/// Log-uniform-ish value: a full-width random word right-shifted by a
/// random amount, hitting every bucket decade the table has.
fn log_uniform(rng: &mut Rng) -> u64 {
    rng.next_u64() >> rng.range(0, 63)
}

const QS: [f64; 9] = [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0];

#[test]
fn prop_quantile_is_within_documented_relative_error_of_empirical_rank() {
    cases(60, |rng| {
        let n = rng.range(1, 2000);
        let h = Histogram::new();
        let mut values = Vec::with_capacity(n);
        for _ in 0..n {
            let v = log_uniform(rng);
            h.record(v);
            values.push(v);
        }
        values.sort_unstable();
        for q in QS {
            // Same rank the histogram documents: clamp(ceil(q*n), 1, n).
            let rank = ((q * n as f64).ceil() as u64).clamp(1, n as u64) as usize;
            let empirical = values[rank - 1];
            let got = h.quantile(q);
            assert!(
                got.abs_diff(empirical).saturating_mul(Histogram::MAX_RELATIVE_ERROR_DEN) <= empirical,
                "quantile error bound broken: q={q} n={n} empirical={empirical} got={got}"
            );
        }
        assert_eq!(h.count(), n as u64);
        assert_eq!(h.min(), values[0], "min is exact");
        assert_eq!(h.max(), values[n - 1], "max is exact");
    });
}

#[test]
fn prop_merged_quantiles_are_bounded_by_the_inputs() {
    cases(60, |rng| {
        let (a, b) = (Histogram::new(), Histogram::new());
        // Different magnitude profiles so the two inputs genuinely
        // disagree about where the mass sits.
        for _ in 0..rng.range(1, 400) {
            a.record(log_uniform(rng) >> 20);
        }
        for _ in 0..rng.range(1, 400) {
            b.record(log_uniform(rng));
        }
        let m = Histogram::new();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.count(), a.count() + b.count());
        for q in QS {
            let (qa, qb, qm) = (a.quantile(q), b.quantile(q), m.quantile(q));
            assert!(
                qa.min(qb) <= qm && qm <= qa.max(qb),
                "merged quantile must lie between its inputs: q={q} a={qa} b={qb} merged={qm}"
            );
        }
    });
}

// ----------------------------------------------------------- wave invariants

#[test]
fn wave_lane_histograms_merge_bucket_exact_into_scheduler_wide() {
    for (seed, total) in [(1u64, 9usize), (42, 14), (2026, 21)] {
        let sched = mixed_lane_wave(seed, total).unwrap();
        let tracer = sched.tracer().expect("wave schedulers trace by default");
        assert_eq!(
            tracer.merged_lanes().snapshot(),
            tracer.overall().snapshot(),
            "per-lane merge must equal the scheduler-wide histogram (seed {seed})"
        );
        // Every wave request (plus the pre-warm) served OK, so each is a
        // latency sample; the queue histogram only sees batched requests.
        assert_eq!(tracer.overall().count(), total as u64 + 1, "seed {seed}");
        assert!(tracer.queue_hist().count() <= tracer.overall().count(), "seed {seed}");
    }
}

// -------------------------------------------------------- protocol coverage

#[test]
fn metrics_exposition_round_trips_with_per_lane_series() {
    let sched = mixed_lane_wave(7, 10).unwrap();
    let text = sched.metrics_text();
    let samples = expo::parse(&text).expect("METRICS must satisfy its own parser");
    for lane in ["gold", "free"] {
        for temp in ["warm", "cold"] {
            assert!(
                samples.iter().any(|s| s.name == "ftl_latency_us_count"
                    && s.labels.iter().any(|(k, v)| k == "lane" && v == lane)
                    && s.labels.iter().any(|(k, v)| k == "temp" && v == temp)),
                "missing latency series for lane={lane} temp={temp}"
            );
        }
    }
    for name in ["ftl_latency_total_us_count", "ftl_queue_us_count"] {
        assert!(samples.iter().any(|s| s.name == name), "missing {name}");
    }
    assert!(samples.iter().all(|s| s.name.starts_with("ftl_")), "all series share the ftl prefix");
    // The protocol entry point serves the same text, newline-trimmed so
    // the connection loop's writeln! terminates it uniformly.
    assert_eq!(handle_command(&sched, "METRICS"), text.trim_end());
}

#[test]
fn stats_carries_server_identity_and_latency_summaries() {
    let sched = mixed_lane_wave(11, 6).unwrap();
    let j = sched.stats_json();
    let server = j.get("server").unwrap();
    assert_eq!(server.get("version").unwrap().as_str().unwrap(), env!("CARGO_PKG_VERSION"));
    assert!(server.get("uptime_ms").unwrap().as_f64().unwrap() >= 0.0);
    assert!(server.get("started_at_unix_ms").unwrap().as_f64().unwrap() > 0.0);
    let lanes = server.get("config").unwrap().get("lanes").unwrap();
    assert!(lanes.get_opt("gold").is_some() && lanes.get_opt("free").is_some());
    let trace = server.get("config").unwrap().get("trace").unwrap();
    assert!(trace.get("enabled").unwrap().as_bool().unwrap());
    let latency = j.get("latency").unwrap();
    assert_eq!(latency.get("overall").unwrap().get("count").unwrap().as_u64().unwrap(), 7);
    assert!(latency.get("lanes").unwrap().get_opt("gold").is_some());
}

#[test]
fn trace_and_slow_dump_monotone_json_spans() {
    // slowlog_ms = 0: every completed request crosses the threshold, so
    // SLOW is populated without needing a genuinely slow solve.
    let service = Arc::new(PlanService::new(ServeOptions::default()));
    let sched = BatchScheduler::new(
        service,
        BatchOptions {
            batch_window: Duration::ZERO,
            trace: TraceOptions { slowlog_ms: 0, ..TraceOptions::default() },
            ..BatchOptions::default()
        },
    );
    let graph = experiments::vit_mlp_stage(16, 24, 48);
    let cfg = DeployConfig::preset("cluster-only", Strategy::Ftl).unwrap();
    sched.deploy("slow-one", graph.clone(), cfg.clone()).unwrap().served().expect("cold serve");
    sched.deploy("warm-one", graph, cfg).unwrap().served().expect("warm serve");

    for cmd in ["TRACE 8", "SLOW 8"] {
        let dump = handle_command(&sched, cmd);
        let mut lines = dump.lines();
        let header = json::parse(lines.next().expect("dump header")).unwrap();
        assert!(header.get("spans").unwrap().as_usize().unwrap() >= 2, "{cmd} must hold both spans");
        let mut saw_ok = false;
        for line in lines {
            let span = json::parse(line).unwrap();
            saw_ok |= span.get("outcome").unwrap().as_str().unwrap() == "OK";
            assert!(span.get("id").unwrap().as_u64().unwrap() >= 1);
            let mut prev = 0u64;
            for key in ["queued_us", "picked_us", "solved_us", "simmed_us", "total_us"] {
                if let Some(v) = span.get_opt(key) {
                    let v = v.as_u64().unwrap();
                    assert!(v >= prev, "{cmd}: stages must be monotone ({key}={v} < {prev})");
                    prev = v;
                }
            }
        }
        assert!(saw_ok, "{cmd} must include the served spans");
    }
}
