//! Crash-restart property test for the serving stack's persistence
//! layer (the in-process soak harness lives in `ftl::soak`; CI drives
//! it end-to-end via `ftl soak` in the soak-smoke step).
//!
//! The property: a server SIGKILLed at a *seeded random point* while
//! the write-behind snapshotter may be mid-flush must warm-start from
//! whatever subset of entries reached disk — never a torn or corrupt
//! entry (every write is tmp + fsync + rename), never a wrong answer
//! on replay, and the work accounting must balance exactly: entries
//! that landed load, entries that were lost re-solve/re-simulate.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use ftl::util::json::{parse, Json};
use ftl::util::prop::Rng;

/// Fresh, empty snapshot dir for one test run.
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ftl-soak-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Ask the kernel for a free port, then release it for the child.
fn free_port() -> u16 {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind :0");
    listener.local_addr().expect("local addr").port()
}

/// One `ftl serve` child over a snapshot dir; SIGKILLed on drop so a
/// failing assert never leaks the process.
struct Server {
    child: Child,
    addr: String,
}

impl Server {
    fn spawn(dir: &Path) -> Server {
        let addr = format!("127.0.0.1:{}", free_port());
        let child = Command::new(env!("CARGO_BIN_EXE_ftl"))
            .arg("serve")
            .args(["--addr", addr.as_str()])
            .arg("--cache-dir")
            .arg(dir)
            // A fast write-behind so the seeded kill delay below lands
            // before, during, or after a flush pass depending on seed.
            .args(["--snapshot-interval-ms", "10"])
            .args(["--batch-window-ms", "2"])
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn ftl serve");
        let mut server = Server { child, addr };
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            if let Some(status) = server.child.try_wait().expect("try_wait") {
                panic!("server exited before becoming ready: {status}");
            }
            if let Ok(j) = roundtrip(&server.addr, "PING") {
                if j.get_opt("pong").is_some() {
                    return server;
                }
            }
            assert!(Instant::now() < deadline, "server at {} not ready within 60s", server.addr);
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    /// SIGKILL + reap — never a graceful shutdown, never a final flush.
    fn kill(mut self) {
        self.child.kill().expect("kill server");
        self.child.wait().expect("reap server");
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// One bare v0 request/reply round trip on a fresh connection.
fn roundtrip(addr: &str, line: &str) -> std::io::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    stream.set_nodelay(true)?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply)?;
    assert!(!reply.is_empty(), "server closed the connection instead of replying to {line:?}");
    Ok(parse(reply.trim_end()).unwrap_or_else(|e| panic!("bad JSON reply to {line:?}: {e} in {reply:?}")))
}

fn num(j: &Json, path: &[&str]) -> u64 {
    let mut cur = j;
    for key in path {
        cur = cur.get(key).unwrap_or_else(|e| panic!("STATS path .{}: {e}", path.join(".")));
    }
    cur.as_u64().unwrap_or_else(|e| panic!("STATS path .{}: {e}", path.join(".")))
}

#[test]
fn kill_mid_flush_restart_recovers_cleanly() {
    let workloads = ["stage-8x16x32", "stage-12x16x32", "stage-8x24x48", "stage-16x16x32"];
    for seed in [11u64, 23] {
        let mut rng = Rng::new(seed);
        let dir = temp_dir(&format!("kill-mid-flush-{seed}"));

        // Serve every workload once and record the answers.
        let server = Server::spawn(&dir);
        let mut cycles: BTreeMap<&str, u64> = BTreeMap::new();
        for w in &workloads {
            let j = roundtrip(&server.addr, &format!("DEPLOY {w} cluster-only ftl")).expect("deploy");
            assert_eq!(j.get("outcome").unwrap().as_str().unwrap(), "OK", "seed {seed}: {w} failed: {j}");
            cycles.insert(w, num(&j, &["sim", "total_cycles"]));
        }

        // SIGKILL at a seeded point relative to the 10ms write-behind:
        // depending on the draw, the flush has not started, is
        // mid-flight, or has finished — all must recover.
        std::thread::sleep(Duration::from_millis(rng.range(0, 25) as u64));
        server.kill();

        // Restart over the same dir: a clean warm start from whatever
        // subset of entries landed, with zero corruption.
        let server = Server::spawn(&dir);
        let boot = roundtrip(&server.addr, "STATS").expect("stats");
        let loaded = num(&boot, &["persist", "loaded"]);
        assert_eq!(
            num(&boot, &["persist", "skipped_corrupt"]),
            0,
            "seed {seed}: atomic writes must never leave a torn entry behind a SIGKILL"
        );
        assert_eq!(num(&boot, &["persist", "skipped_version"]), 0, "seed {seed}: no version skips");
        assert!(
            loaded <= 2 * workloads.len() as u64,
            "seed {seed}: at most one plan + one sim entry per workload can load, got {loaded}"
        );

        // Replay: identical answers, whether served warm or re-solved.
        for w in &workloads {
            let j = roundtrip(&server.addr, &format!("DEPLOY {w} cluster-only ftl")).expect("replay");
            assert_eq!(j.get("outcome").unwrap().as_str().unwrap(), "OK", "seed {seed}: {w} replay failed: {j}");
            assert_eq!(
                num(&j, &["sim", "total_cycles"]),
                cycles[w],
                "seed {seed}: {w} must re-simulate to the same answer after the crash"
            );
        }

        // Work accounting balances exactly: every entry the warm start
        // did not load was recomputed, nothing more (the solver is
        // deterministic, so a re-solved plan re-derives the same sim
        // key and a surviving sim entry still hits).
        let stats = roundtrip(&server.addr, "STATS").expect("stats");
        let recomputed = num(&stats, &["solves"]) + num(&stats, &["sims"]);
        assert_eq!(
            recomputed + loaded,
            2 * workloads.len() as u64,
            "seed {seed}: loaded {loaded} + recomputed {recomputed} must cover every plan + sim entry"
        );
        assert_eq!(num(&stats, &["persist", "write_errors"]), 0, "seed {seed}: no write errors");

        server.kill();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
